//! The AOT manifest: the cross-language contract with `python/compile`.
//!
//! `artifacts/manifest.json` records, per model variant, the flat
//! parameter layout (tensor names/shapes/offsets/init kinds) and, per
//! entry point (train/grad/encode/score), the exact argument order,
//! dtypes, shapes and the HLO file per kernel implementation. The rust
//! side packs literals by *name* against this spec, so a drift between
//! the two languages fails loudly here rather than as silent garbage.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Scalar dtype of an artifact argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// Parameter-tensor initialisation kind (mirrors model.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitKind {
    Glorot,
    Zeros,
    Ones,
    Prelu,
    Normal,
}

/// One named tensor inside the flat parameter vector.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
    pub offset: usize,
}

impl TensorSpec {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One argument (or output) of an entry point.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One entry point (train / grad / encode / score) of a variant.
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
    /// impl name ("pallas" | "jnp") -> HLO text file name.
    pub artifacts: BTreeMap<String, String>,
}

/// One (encoder, decoder) model variant.
#[derive(Clone, Debug)]
pub struct VariantSpec {
    pub name: String,
    pub encoder: String,
    pub decoder: String,
    pub hetero: bool,
    pub param_total: usize,
    pub tensors: Vec<TensorSpec>,
    pub entries: BTreeMap<String, EntrySpec>,
}

/// Global model dimensions shared by all variants.
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    pub feat_dim: usize,
    pub hidden: usize,
    pub block_nodes: usize,
    pub block_edges: usize,
    pub score_batch: usize,
    pub relations: usize,
}

/// Adam hyperparameters baked into the train artifacts (and used by the
/// rust-side optimizer for GGS).
#[derive(Clone, Copy, Debug)]
pub struct AdamHp {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

/// Parsed manifest plus the artifact directory it came from.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub adam: AdamHp,
    pub dims: ModelDims,
    pub variants: BTreeMap<String, VariantSpec>,
    /// Compute backend this manifest selects ("native" | "pjrt").
    /// Resolution precedence: manifest JSON field (default "native")
    /// < `RTMA_BACKEND` env var < `--backend` CLI flag (the CLI layer
    /// overwrites this field; see `runtime::load_backend`).
    pub backend: String,
}

/// Apply the manifest-field < `RTMA_BACKEND` half of the backend
/// precedence chain (the CLI flag overwrites the result later).
fn resolve_backend(from_manifest: Option<&str>) -> String {
    match std::env::var("RTMA_BACKEND") {
        Ok(v) if !v.is_empty() => v,
        _ => from_manifest.unwrap_or("native").to_string(),
    }
}

fn parse_dtype(s: &str) -> Result<Dtype> {
    match s {
        "f32" => Ok(Dtype::F32),
        "i32" => Ok(Dtype::I32),
        other => bail!("unknown dtype {other:?}"),
    }
}

fn parse_init(s: &str) -> Result<InitKind> {
    Ok(match s {
        "glorot" => InitKind::Glorot,
        "zeros" => InitKind::Zeros,
        "ones" => InitKind::Ones,
        "prelu" => InitKind::Prelu,
        "normal" => InitKind::Normal,
        other => bail!("unknown init kind {other:?}"),
    })
}

fn parse_arg(j: &Json) -> Result<ArgSpec> {
    Ok(ArgSpec {
        name: j.get("name").as_str().context("arg name")?.to_string(),
        dtype: parse_dtype(j.get("dtype").as_str().context("arg dtype")?)?,
        shape: j
            .get("shape")
            .as_arr()
            .context("arg shape")?
            .iter()
            .map(|x| x.as_usize().context("shape dim"))
            .collect::<Result<_>>()?,
    })
}

impl Manifest {
    /// Default artifact directory (`artifacts/` beside the workspace).
    pub fn default_dir() -> PathBuf {
        PathBuf::from("artifacts")
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::read_file(&dir.join("manifest.json"))?;
        let adam = AdamHp {
            lr: j.at(&["adam", "lr"]).as_f64().context("adam.lr")? as f32,
            beta1: j.at(&["adam", "beta1"]).as_f64().context("adam.beta1")? as f32,
            beta2: j.at(&["adam", "beta2"]).as_f64().context("adam.beta2")? as f32,
            eps: j.at(&["adam", "eps"]).as_f64().context("adam.eps")? as f32,
        };
        let c = j.get("config");
        let dims = ModelDims {
            feat_dim: c.get("feat_dim").as_usize().context("feat_dim")?,
            hidden: c.get("hidden").as_usize().context("hidden")?,
            block_nodes: c.get("block_nodes").as_usize().context("block_nodes")?,
            block_edges: c.get("block_edges").as_usize().context("block_edges")?,
            score_batch: c.get("score_batch").as_usize().context("score_batch")?,
            relations: c.get("relations").as_usize().context("relations")?,
        };

        let mut variants = BTreeMap::new();
        for (vname, vj) in j.get("variants").as_obj().context("variants")? {
            let mut tensors = Vec::new();
            for tj in vj.at(&["params", "tensors"]).as_arr().context("tensors")? {
                tensors.push(TensorSpec {
                    name: tj.get("name").as_str().context("t name")?.to_string(),
                    shape: tj
                        .get("shape")
                        .as_arr()
                        .context("t shape")?
                        .iter()
                        .map(|x| x.as_usize().unwrap())
                        .collect(),
                    init: parse_init(tj.get("init").as_str().context("t init")?)?,
                    offset: tj.get("offset").as_usize().context("t offset")?,
                });
            }
            let mut entries = BTreeMap::new();
            for (ename, ej) in vj.get("entries").as_obj().context("entries")? {
                let args = ej
                    .get("args")
                    .as_arr()
                    .context("args")?
                    .iter()
                    .map(parse_arg)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = ej
                    .get("outputs")
                    .as_arr()
                    .context("outputs")?
                    .iter()
                    .map(parse_arg)
                    .collect::<Result<Vec<_>>>()?;
                let artifacts = ej
                    .get("artifacts")
                    .as_obj()
                    .context("artifacts")?
                    .iter()
                    .map(|(k, v)| (k.clone(), v.as_str().unwrap().to_string()))
                    .collect();
                entries.insert(ename.clone(), EntrySpec { args, outputs, artifacts });
            }
            variants.insert(
                vname.clone(),
                VariantSpec {
                    name: vname.clone(),
                    encoder: vj.get("encoder").as_str().context("encoder")?.to_string(),
                    decoder: vj.get("decoder").as_str().context("decoder")?.to_string(),
                    hetero: vj.get("hetero").as_bool().context("hetero")?,
                    param_total: vj.at(&["params", "total"]).as_usize().context("total")?,
                    tensors,
                    entries,
                },
            );
        }
        let backend = resolve_backend(j.get("backend").as_str());
        Ok(Manifest { dir: dir.to_path_buf(), adam, dims, variants, backend })
    }

    /// The real artifact manifest when one is built, else the
    /// [`Self::builtin`] layout — every binary entry point uses this,
    /// so a bare checkout trains on the native backend instead of
    /// dying on "artifacts missing".
    pub fn load_or_builtin() -> Manifest {
        match Manifest::load(&Manifest::default_dir()) {
            Ok(m) => m,
            Err(_) => Manifest::builtin(),
        }
    }

    /// Synthetic manifest with the paper's default shapes (F=64 H=64
    /// Bn=256 Be=128 S=2048 R=4, 2 encoder + 2 decoder layers, 4 rgcn
    /// bases) — byte-for-byte the layout `python/compile/model.py::
    /// build_layout` emits, minus the HLO artifact files. The native
    /// backend needs nothing else.
    pub fn builtin() -> Manifest {
        Manifest::builtin_sized(
            ModelDims {
                feat_dim: 64,
                hidden: 64,
                block_nodes: 256,
                block_edges: 128,
                score_batch: 2048,
                relations: 4,
            },
            2,
            2,
            4,
        )
    }

    /// [`Self::builtin`] with explicit dimensions — the unit tests use
    /// tiny shapes so finite-difference gradient checks stay cheap.
    pub fn builtin_sized(
        dims: ModelDims,
        layers: usize,
        dec_layers: usize,
        bases: usize,
    ) -> Manifest {
        let adam = AdamHp { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8 };
        let mut variants = BTreeMap::new();
        for (enc, dec) in [
            ("gcn", "mlp"),
            ("sage", "mlp"),
            ("mlp", "mlp"),
            ("gcn", "distmult"),
            ("rgcn", "mlp"),
            ("rgcn", "distmult"),
        ] {
            let v = builtin_variant(&dims, enc, dec, layers, dec_layers, bases);
            variants.insert(v.name.clone(), v);
        }
        Manifest {
            dir: PathBuf::from("builtin"),
            adam,
            dims,
            variants,
            backend: resolve_backend(None),
        }
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants
            .get(name)
            .with_context(|| format!("variant {name:?} not in manifest"))
    }
}

/// One variant of the builtin layout, mirroring `build_layout` +
/// `make_entry_points` in `python/compile/model.py` (same tensor
/// order/naming/init and the same entry argument order — the
/// cross-language contract, now testable without artifacts).
fn builtin_variant(
    dims: &ModelDims,
    enc: &str,
    dec: &str,
    layers: usize,
    dec_layers: usize,
    bases: usize,
) -> VariantSpec {
    let (f, h, r) = (dims.feat_dim, dims.hidden, dims.relations);
    let hetero = enc == "rgcn" || dec == "distmult";

    fn push(
        tensors: &mut Vec<TensorSpec>,
        off: &mut usize,
        name: String,
        shape: Vec<usize>,
        init: InitKind,
    ) {
        let size: usize = shape.iter().product();
        tensors.push(TensorSpec { name, shape, init, offset: *off });
        *off += size;
    }

    let mut tensors = Vec::new();
    let mut off = 0usize;
    for l in 0..layers {
        let d_in = if l == 0 { f } else { h };
        let p = format!("enc{l}");
        match enc {
            "gcn" | "mlp" => {
                push(&mut tensors, &mut off, format!("{p}.w"), vec![d_in, h], InitKind::Glorot);
            }
            "sage" => {
                push(&mut tensors, &mut off, format!("{p}.w_self"), vec![d_in, h], InitKind::Glorot);
                push(&mut tensors, &mut off, format!("{p}.w_nbr"), vec![d_in, h], InitKind::Glorot);
            }
            "rgcn" => {
                push(&mut tensors, &mut off, format!("{p}.w_self"), vec![d_in, h], InitKind::Glorot);
                push(&mut tensors, &mut off, format!("{p}.basis"), vec![bases, d_in, h], InitKind::Glorot);
                push(&mut tensors, &mut off, format!("{p}.coeff"), vec![r, bases], InitKind::Glorot);
            }
            other => unreachable!("builtin encoder {other}"),
        }
        push(&mut tensors, &mut off, format!("{p}.b"), vec![h], InitKind::Zeros);
        push(&mut tensors, &mut off, format!("{p}.ln_scale"), vec![h], InitKind::Ones);
        push(&mut tensors, &mut off, format!("{p}.ln_bias"), vec![h], InitKind::Zeros);
        push(&mut tensors, &mut off, format!("{p}.prelu"), vec![1], InitKind::Prelu);
    }
    if dec == "mlp" {
        for l in 0..dec_layers {
            let d_out = if l == dec_layers - 1 { 1 } else { h };
            let p = format!("dec{l}");
            push(&mut tensors, &mut off, format!("{p}.w"), vec![h, d_out], InitKind::Glorot);
            push(&mut tensors, &mut off, format!("{p}.b"), vec![d_out], InitKind::Zeros);
            if l != dec_layers - 1 {
                push(&mut tensors, &mut off, format!("{p}.prelu"), vec![1], InitKind::Prelu);
            }
        }
    } else {
        push(&mut tensors, &mut off, "dec.rel".to_string(), vec![r, h], InitKind::Normal);
    }
    let param_total = off;

    let farg = |name: &str, shape: Vec<usize>| ArgSpec {
        name: name.to_string(),
        dtype: Dtype::F32,
        shape,
    };
    let iarg = |name: &str, shape: Vec<usize>| ArgSpec {
        name: name.to_string(),
        dtype: Dtype::I32,
        shape,
    };
    let (bn, be, sb) = (dims.block_nodes, dims.block_edges, dims.score_batch);
    let adj_shape = if enc == "rgcn" { vec![r, bn, bn] } else { vec![bn, bn] };
    let mut batch = vec![
        farg("feats", vec![bn, f]),
        farg("adj", adj_shape.clone()),
        iarg("pos_u", vec![be]),
        iarg("pos_v", vec![be]),
    ];
    if hetero {
        batch.push(iarg("rel", vec![be]));
    }
    batch.push(iarg("neg_v", vec![be]));
    batch.push(farg("mask", vec![be]));

    let opt = vec![
        farg("params", vec![param_total]),
        farg("adam_m", vec![param_total]),
        farg("adam_v", vec![param_total]),
        farg("adam_t", vec![1]),
    ];
    let mut entries = BTreeMap::new();
    entries.insert(
        "train".to_string(),
        EntrySpec {
            args: opt.iter().cloned().chain(batch.iter().cloned()).collect(),
            outputs: vec![
                farg("params", vec![param_total]),
                farg("adam_m", vec![param_total]),
                farg("adam_v", vec![param_total]),
                farg("adam_t", vec![1]),
                farg("loss", vec![]),
            ],
            artifacts: BTreeMap::new(),
        },
    );
    entries.insert(
        "grad".to_string(),
        EntrySpec {
            args: std::iter::once(farg("params", vec![param_total]))
                .chain(batch.iter().cloned())
                .collect(),
            outputs: vec![farg("grad", vec![param_total]), farg("loss", vec![])],
            artifacts: BTreeMap::new(),
        },
    );
    entries.insert(
        "encode".to_string(),
        EntrySpec {
            args: vec![
                farg("params", vec![param_total]),
                farg("feats", vec![bn, f]),
                farg("adj", adj_shape),
            ],
            outputs: vec![farg("emb", vec![bn, h])],
            artifacts: BTreeMap::new(),
        },
    );
    let mut score_args = vec![
        farg("params", vec![param_total]),
        farg("emb_u", vec![sb, h]),
        farg("emb_v", vec![sb, h]),
    ];
    if dec == "distmult" {
        score_args.push(iarg("rel", vec![sb]));
    }
    entries.insert(
        "score".to_string(),
        EntrySpec {
            args: score_args,
            outputs: vec![farg("scores", vec![sb])],
            artifacts: BTreeMap::new(),
        },
    );

    VariantSpec {
        name: format!("{enc}_{dec}"),
        encoder: enc.to_string(),
        decoder: dec.to_string(),
        hetero,
        param_total,
        tensors,
        entries,
    }
}

impl VariantSpec {
    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .with_context(|| format!("entry {name:?} of {}", self.name))
    }

    /// HLO file path for an entry in a given kernel implementation.
    pub fn artifact_path(
        &self,
        dir: &Path,
        entry: &str,
        impl_name: &str,
    ) -> Result<PathBuf> {
        let e = self.entry(entry)?;
        let f = e
            .artifacts
            .get(impl_name)
            .with_context(|| format!("impl {impl_name:?} for {entry}"))?;
        Ok(dir.join(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        // tests run from the workspace root
        PathBuf::from("artifacts")
    }

    fn skip_if_missing() -> Option<Manifest> {
        let dir = manifest_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Manifest::load(&dir).expect("manifest parses"))
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = skip_if_missing() else { return };
        assert!((m.adam.lr - 1e-3).abs() < 1e-9);
        assert_eq!(m.dims.block_nodes, 256);
        for v in ["gcn_mlp", "sage_mlp", "mlp_mlp", "rgcn_distmult"] {
            assert!(m.variants.contains_key(v), "{v} missing");
        }
    }

    #[test]
    fn layouts_are_packed() {
        let Some(m) = skip_if_missing() else { return };
        for v in m.variants.values() {
            let mut off = 0;
            for t in &v.tensors {
                assert_eq!(t.offset, off, "{}.{}", v.name, t.name);
                off += t.size();
            }
            assert_eq!(off, v.param_total, "{}", v.name);
        }
    }

    #[test]
    fn entry_args_start_with_params() {
        let Some(m) = skip_if_missing() else { return };
        for v in m.variants.values() {
            for (ename, e) in &v.entries {
                assert_eq!(e.args[0].name, "params", "{}/{}", v.name, ename);
                assert_eq!(e.args[0].shape, vec![v.param_total]);
                assert_eq!(e.args[0].dtype, Dtype::F32);
                for impl_name in ["pallas", "jnp"] {
                    let p = v
                        .artifact_path(&m.dir, ename, impl_name)
                        .unwrap();
                    assert!(p.exists(), "{}", p.display());
                }
            }
        }
    }

    #[test]
    fn train_entry_has_adam_state() {
        let Some(m) = skip_if_missing() else { return };
        let v = m.variant("gcn_mlp").unwrap();
        let names: Vec<_> = v
            .entry("train")
            .unwrap()
            .args
            .iter()
            .map(|a| a.name.as_str())
            .collect();
        assert_eq!(
            &names[..4],
            &["params", "adam_m", "adam_v", "adam_t"]
        );
        assert!(names.contains(&"feats"));
        assert!(names.contains(&"mask"));
    }

    #[test]
    fn hetero_variants_have_rel_arg() {
        let Some(m) = skip_if_missing() else { return };
        for vname in ["gcn_distmult", "rgcn_mlp", "rgcn_distmult"] {
            let v = m.variant(vname).unwrap();
            assert!(v.hetero, "{vname}");
            let names: Vec<_> = v
                .entry("train")
                .unwrap()
                .args
                .iter()
                .map(|a| a.name.as_str())
                .collect();
            assert!(names.contains(&"rel"), "{vname}: {names:?}");
        }
    }

    // ---- builtin manifest (always-on: no artifacts involved) ----

    #[test]
    fn builtin_has_all_six_variants_packed() {
        let m = Manifest::builtin();
        assert_eq!(m.variants.len(), 6);
        for v in m.variants.values() {
            let mut off = 0;
            for t in &v.tensors {
                assert_eq!(t.offset, off, "{}.{}", v.name, t.name);
                off += t.size();
            }
            assert_eq!(off, v.param_total, "{}", v.name);
        }
        // Hand-summed paper-default gcn_mlp layout: 2 × (64·64 W +
        // 64 b + 64 ln_scale + 64 ln_bias + 1 prelu) + dec0 (64·64 +
        // 64 + 1) + dec1 (64 + 1).
        assert_eq!(m.variant("gcn_mlp").unwrap().param_total, 12804);
    }

    #[test]
    fn builtin_entry_args_match_model_py_order() {
        let m = Manifest::builtin();
        for v in m.variants.values() {
            for (ename, e) in &v.entries {
                assert_eq!(e.args[0].name, "params", "{}/{}", v.name, ename);
                assert_eq!(e.args[0].shape, vec![v.param_total]);
            }
            let train: Vec<_> = v
                .entry("train")
                .unwrap()
                .args
                .iter()
                .map(|a| a.name.as_str())
                .collect();
            assert_eq!(&train[..4], &["params", "adam_m", "adam_v", "adam_t"]);
            assert_eq!(
                train.contains(&"rel"),
                v.hetero,
                "{}: {train:?}",
                v.name
            );
            // grad = train minus the Adam state.
            let grad: Vec<_> = v
                .entry("grad")
                .unwrap()
                .args
                .iter()
                .map(|a| a.name.as_str())
                .collect();
            assert_eq!(&train[3 + 1..], &grad[1..]);
        }
        // rgcn adjacency is per-relation.
        let v = m.variant("rgcn_distmult").unwrap();
        let adj = v
            .entry("train")
            .unwrap()
            .args
            .iter()
            .find(|a| a.name == "adj")
            .unwrap();
        assert_eq!(adj.shape, vec![4, 256, 256]);
    }

    #[test]
    fn builtin_defaults_to_native_backend() {
        if std::env::var("RTMA_BACKEND").is_ok() {
            return; // respect an explicit override in the environment
        }
        assert_eq!(Manifest::builtin().backend, "native");
        assert_eq!(Manifest::load_or_builtin().backend, "native");
    }

    #[test]
    fn builtin_hetero_flags_match_model_py() {
        let m = Manifest::builtin();
        for (name, hetero) in [
            ("gcn_mlp", false),
            ("sage_mlp", false),
            ("mlp_mlp", false),
            ("gcn_distmult", true),
            ("rgcn_mlp", true),
            ("rgcn_distmult", true),
        ] {
            assert_eq!(m.variant(name).unwrap().hetero, hetero, "{name}");
        }
    }
}
