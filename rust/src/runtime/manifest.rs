//! The AOT manifest: the cross-language contract with `python/compile`.
//!
//! `artifacts/manifest.json` records, per model variant, the flat
//! parameter layout (tensor names/shapes/offsets/init kinds) and, per
//! entry point (train/grad/encode/score), the exact argument order,
//! dtypes, shapes and the HLO file per kernel implementation. The rust
//! side packs literals by *name* against this spec, so a drift between
//! the two languages fails loudly here rather than as silent garbage.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Scalar dtype of an artifact argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// Parameter-tensor initialisation kind (mirrors model.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitKind {
    Glorot,
    Zeros,
    Ones,
    Prelu,
    Normal,
}

/// One named tensor inside the flat parameter vector.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
    pub offset: usize,
}

impl TensorSpec {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One argument (or output) of an entry point.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One entry point (train / grad / encode / score) of a variant.
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
    /// impl name ("pallas" | "jnp") -> HLO text file name.
    pub artifacts: BTreeMap<String, String>,
}

/// One (encoder, decoder) model variant.
#[derive(Clone, Debug)]
pub struct VariantSpec {
    pub name: String,
    pub encoder: String,
    pub decoder: String,
    pub hetero: bool,
    pub param_total: usize,
    pub tensors: Vec<TensorSpec>,
    pub entries: BTreeMap<String, EntrySpec>,
}

/// Global model dimensions shared by all variants.
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    pub feat_dim: usize,
    pub hidden: usize,
    pub block_nodes: usize,
    pub block_edges: usize,
    pub score_batch: usize,
    pub relations: usize,
}

/// Adam hyperparameters baked into the train artifacts (and used by the
/// rust-side optimizer for GGS).
#[derive(Clone, Copy, Debug)]
pub struct AdamHp {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

/// Parsed manifest plus the artifact directory it came from.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub adam: AdamHp,
    pub dims: ModelDims,
    pub variants: BTreeMap<String, VariantSpec>,
}

fn parse_dtype(s: &str) -> Result<Dtype> {
    match s {
        "f32" => Ok(Dtype::F32),
        "i32" => Ok(Dtype::I32),
        other => bail!("unknown dtype {other:?}"),
    }
}

fn parse_init(s: &str) -> Result<InitKind> {
    Ok(match s {
        "glorot" => InitKind::Glorot,
        "zeros" => InitKind::Zeros,
        "ones" => InitKind::Ones,
        "prelu" => InitKind::Prelu,
        "normal" => InitKind::Normal,
        other => bail!("unknown init kind {other:?}"),
    })
}

fn parse_arg(j: &Json) -> Result<ArgSpec> {
    Ok(ArgSpec {
        name: j.get("name").as_str().context("arg name")?.to_string(),
        dtype: parse_dtype(j.get("dtype").as_str().context("arg dtype")?)?,
        shape: j
            .get("shape")
            .as_arr()
            .context("arg shape")?
            .iter()
            .map(|x| x.as_usize().context("shape dim"))
            .collect::<Result<_>>()?,
    })
}

impl Manifest {
    /// Default artifact directory (`artifacts/` beside the workspace).
    pub fn default_dir() -> PathBuf {
        PathBuf::from("artifacts")
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::read_file(&dir.join("manifest.json"))?;
        let adam = AdamHp {
            lr: j.at(&["adam", "lr"]).as_f64().context("adam.lr")? as f32,
            beta1: j.at(&["adam", "beta1"]).as_f64().context("adam.beta1")? as f32,
            beta2: j.at(&["adam", "beta2"]).as_f64().context("adam.beta2")? as f32,
            eps: j.at(&["adam", "eps"]).as_f64().context("adam.eps")? as f32,
        };
        let c = j.get("config");
        let dims = ModelDims {
            feat_dim: c.get("feat_dim").as_usize().context("feat_dim")?,
            hidden: c.get("hidden").as_usize().context("hidden")?,
            block_nodes: c.get("block_nodes").as_usize().context("block_nodes")?,
            block_edges: c.get("block_edges").as_usize().context("block_edges")?,
            score_batch: c.get("score_batch").as_usize().context("score_batch")?,
            relations: c.get("relations").as_usize().context("relations")?,
        };

        let mut variants = BTreeMap::new();
        for (vname, vj) in j.get("variants").as_obj().context("variants")? {
            let mut tensors = Vec::new();
            for tj in vj.at(&["params", "tensors"]).as_arr().context("tensors")? {
                tensors.push(TensorSpec {
                    name: tj.get("name").as_str().context("t name")?.to_string(),
                    shape: tj
                        .get("shape")
                        .as_arr()
                        .context("t shape")?
                        .iter()
                        .map(|x| x.as_usize().unwrap())
                        .collect(),
                    init: parse_init(tj.get("init").as_str().context("t init")?)?,
                    offset: tj.get("offset").as_usize().context("t offset")?,
                });
            }
            let mut entries = BTreeMap::new();
            for (ename, ej) in vj.get("entries").as_obj().context("entries")? {
                let args = ej
                    .get("args")
                    .as_arr()
                    .context("args")?
                    .iter()
                    .map(parse_arg)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = ej
                    .get("outputs")
                    .as_arr()
                    .context("outputs")?
                    .iter()
                    .map(parse_arg)
                    .collect::<Result<Vec<_>>>()?;
                let artifacts = ej
                    .get("artifacts")
                    .as_obj()
                    .context("artifacts")?
                    .iter()
                    .map(|(k, v)| (k.clone(), v.as_str().unwrap().to_string()))
                    .collect();
                entries.insert(ename.clone(), EntrySpec { args, outputs, artifacts });
            }
            variants.insert(
                vname.clone(),
                VariantSpec {
                    name: vname.clone(),
                    encoder: vj.get("encoder").as_str().context("encoder")?.to_string(),
                    decoder: vj.get("decoder").as_str().context("decoder")?.to_string(),
                    hetero: vj.get("hetero").as_bool().context("hetero")?,
                    param_total: vj.at(&["params", "total"]).as_usize().context("total")?,
                    tensors,
                    entries,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), adam, dims, variants })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants
            .get(name)
            .with_context(|| format!("variant {name:?} not in manifest"))
    }
}

impl VariantSpec {
    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .with_context(|| format!("entry {name:?} of {}", self.name))
    }

    /// HLO file path for an entry in a given kernel implementation.
    pub fn artifact_path(
        &self,
        dir: &Path,
        entry: &str,
        impl_name: &str,
    ) -> Result<PathBuf> {
        let e = self.entry(entry)?;
        let f = e
            .artifacts
            .get(impl_name)
            .with_context(|| format!("impl {impl_name:?} for {entry}"))?;
        Ok(dir.join(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        // tests run from the workspace root
        PathBuf::from("artifacts")
    }

    fn skip_if_missing() -> Option<Manifest> {
        let dir = manifest_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Manifest::load(&dir).expect("manifest parses"))
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = skip_if_missing() else { return };
        assert!((m.adam.lr - 1e-3).abs() < 1e-9);
        assert_eq!(m.dims.block_nodes, 256);
        for v in ["gcn_mlp", "sage_mlp", "mlp_mlp", "rgcn_distmult"] {
            assert!(m.variants.contains_key(v), "{v} missing");
        }
    }

    #[test]
    fn layouts_are_packed() {
        let Some(m) = skip_if_missing() else { return };
        for v in m.variants.values() {
            let mut off = 0;
            for t in &v.tensors {
                assert_eq!(t.offset, off, "{}.{}", v.name, t.name);
                off += t.size();
            }
            assert_eq!(off, v.param_total, "{}", v.name);
        }
    }

    #[test]
    fn entry_args_start_with_params() {
        let Some(m) = skip_if_missing() else { return };
        for v in m.variants.values() {
            for (ename, e) in &v.entries {
                assert_eq!(e.args[0].name, "params", "{}/{}", v.name, ename);
                assert_eq!(e.args[0].shape, vec![v.param_total]);
                assert_eq!(e.args[0].dtype, Dtype::F32);
                for impl_name in ["pallas", "jnp"] {
                    let p = v
                        .artifact_path(&m.dir, ename, impl_name)
                        .unwrap();
                    assert!(p.exists(), "{}", p.display());
                }
            }
        }
    }

    #[test]
    fn train_entry_has_adam_state() {
        let Some(m) = skip_if_missing() else { return };
        let v = m.variant("gcn_mlp").unwrap();
        let names: Vec<_> = v
            .entry("train")
            .unwrap()
            .args
            .iter()
            .map(|a| a.name.as_str())
            .collect();
        assert_eq!(
            &names[..4],
            &["params", "adam_m", "adam_v", "adam_t"]
        );
        assert!(names.contains(&"feats"));
        assert!(names.contains(&"mask"));
    }

    #[test]
    fn hetero_variants_have_rel_arg() {
        let Some(m) = skip_if_missing() else { return };
        for vname in ["gcn_distmult", "rgcn_mlp", "rgcn_distmult"] {
            let v = m.variant(vname).unwrap();
            assert!(v.hetero, "{vname}");
            let names: Vec<_> = v
                .entry("train")
                .unwrap()
                .args
                .iter()
                .map(|a| a.name.as_str())
                .collect();
            assert!(names.contains(&"rel"), "{vname}: {names:?}");
        }
    }
}
