//! The pure-Rust native backend: the manifest's four entry points
//! (`train`/`grad`/`encode`/`score`) implemented directly on the flat
//! parameter vector, with no PJRT artifacts and no external deps.
//!
//! The kernels mirror `python/compile/kernels/ref.py` and the model
//! math in `python/compile/model.py` exactly (same summation order,
//! same LayerNorm eps, same fused-Adam bias correction), so the
//! differential suite can compare against both hand-checked golden
//! values (always-on, `tests/native_engine.rs`) and the PJRT
//! artifacts within tolerance (artifact-gated, `tests/integration.rs`).
//!
//! Design notes:
//! - **Alloc-free hot loop**: every buffer the forward/backward pass
//!   touches lives in a per-engine [`Scratch`] sized once at
//!   construction; `train_step`/`grad_step`/`encode`/`score` allocate
//!   nothing after warmup except the output vectors their signatures
//!   return.
//! - **Cache-blocked parallel matmul**: [`mm`] splits output rows
//!   across [`crate::util::threadpool::parallel_fill`] windows and
//!   k-tiles the inner kernel; per output element the adds happen in
//!   ascending-k order regardless of worker count or tile size, so
//!   results are bit-deterministic on any machine.
//! - **CSR aggregation**: dense block adjacency is compacted to CSR
//!   once per call ([`Csr::from_dense`], reusing its buffers), then
//!   `adj @ x` and the backward `adjᵀ @ d` are sparse row sweeps —
//!   sampled blocks are >90% zeros at the paper's fanouts.
//! - Entry points are wrapped in telemetry spans feeding the
//!   `engine_*` histograms (see `docs/TELEMETRY.md`).

use std::cell::RefCell;

use anyhow::{bail, ensure, Context, Result};

use crate::model::ModelState;
use crate::sampler::Block;
use crate::telemetry::{metrics, Span};
use crate::util::threadpool;

use super::manifest::{AdamHp, Manifest, ModelDims, TensorSpec, VariantSpec};

// ------------------------------------------------------------------
// Scalar kernels
// ------------------------------------------------------------------

/// LayerNorm epsilon (mirrors `model.py::layer_norm`).
pub const LN_EPS: f32 = 1e-5;

/// Numerically stable `log(1 + e^x)` (mirrors `jax.nn.softplus`).
pub fn softplus(x: f32) -> f32 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

/// Logistic sigmoid.
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

// ------------------------------------------------------------------
// Dense matmul kernels (ref.py: mm / mm_nt / mm_tn)
// ------------------------------------------------------------------

/// Below this many multiply-adds a serial pass beats spawning scoped
/// threads (same budget reasoning as `MeanAccum::PAR_MIN`).
const MM_PAR_MIN: usize = 1 << 20;

/// k-tile width for the inner kernel: one `b` panel of 64 rows stays
/// resident in L1/L2 while a row chunk streams over it.
const MM_KB: usize = 64;

/// `out[m,n] = a[m,k] @ b[k,n]` (row-major). Large products split
/// output rows across threadpool workers; a dot product is never
/// split, so any worker count produces identical bits.
pub fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "mm: a shape");
    assert_eq!(b.len(), k * n, "mm: b shape");
    assert_eq!(out.len(), m * n, "mm: out shape");
    let workers = threadpool::default_workers();
    if workers > 1 && m > 1 && m * k * n >= MM_PAR_MIN {
        let parts = workers.min(m);
        let rows = threadpool::even_chunks(m, parts);
        let sizes: Vec<usize> = rows.iter().map(|&r| r * n).collect();
        let mut starts = Vec::with_capacity(parts);
        let mut next = 0usize;
        for &r in &rows {
            starts.push(next);
            next += r;
        }
        threadpool::parallel_fill(out, &sizes, parts, |i, win| {
            let r0 = starts[i];
            let nr = rows[i];
            mm_rows(&a[r0 * k..(r0 + nr) * k], b, nr, k, n, win);
        });
    } else {
        mm_rows(a, b, m, k, n, out);
    }
}

/// Serial k-tiled kernel for a window of output rows. Zero `a`
/// entries are skipped (sampled blocks are mostly padding), which
/// never changes the result: per output element the non-skipped adds
/// still happen in ascending-k order.
fn mm_rows(a: &[f32], b: &[f32], rows: usize, k: usize, n: usize, out: &mut [f32]) {
    out.fill(0.0);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + MM_KB).min(k);
        for i in 0..rows {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for c in 0..n {
                    orow[c] += av * brow[c];
                }
            }
        }
        k0 = k1;
    }
}

/// `out[m,n] = a[m,k] @ bᵀ` with `b` stored `[n,k]` (ref.py `mm_nt`).
pub fn mm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    out.fill(0.0);
    mm_nt_acc(a, b, m, k, n, out);
}

/// Accumulating variant of [`mm_nt`]: `out += a @ bᵀ`.
pub fn mm_nt_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "mm_nt: a shape");
    assert_eq!(b.len(), n * k, "mm_nt: b shape");
    assert_eq!(out.len(), m * n, "mm_nt: out shape");
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for t in 0..k {
                acc += arow[t] * brow[t];
            }
            orow[j] += acc;
        }
    }
}

/// `out[m,n] = aᵀ @ b` with `a` stored `[k,m]` (ref.py `mm_tn`).
pub fn mm_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, out: &mut [f32]) {
    out.fill(0.0);
    mm_tn_acc(a, b, k, m, n, out);
}

/// Accumulating variant of [`mm_tn`]: `out += aᵀ @ b`. This is the
/// weight-gradient kernel (`xᵀ @ d`), so it accumulates into the flat
/// gradient slice directly.
pub fn mm_tn_acc(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), k * m, "mm_tn: a shape");
    assert_eq!(b.len(), k * n, "mm_tn: b shape");
    assert_eq!(out.len(), m * n, "mm_tn: out shape");
    for t in 0..k {
        let arow = &a[t * m..(t + 1) * m];
        let brow = &b[t * n..(t + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

// ------------------------------------------------------------------
// Sparse block adjacency
// ------------------------------------------------------------------

/// CSR view of one dense block adjacency, rebuilt in place each call
/// (the index/value buffers are reused, so steady-state rebuilds
/// allocate nothing).
#[derive(Default)]
pub struct Csr {
    rows: usize,
    row_ptr: Vec<usize>,
    cols: Vec<u32>,
    vals: Vec<f32>,
}

impl Csr {
    pub fn new() -> Csr {
        Csr::default()
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Compact a row-major dense `[rows, cols]` matrix, keeping the
    /// existing buffers.
    pub fn from_dense(&mut self, dense: &[f32], rows: usize, cols: usize) {
        assert_eq!(dense.len(), rows * cols, "csr: dense shape");
        self.rows = rows;
        self.row_ptr.clear();
        self.cols.clear();
        self.vals.clear();
        self.row_ptr.push(0);
        for i in 0..rows {
            let drow = &dense[i * cols..(i + 1) * cols];
            for (j, &v) in drow.iter().enumerate() {
                if v != 0.0 {
                    self.cols.push(j as u32);
                    self.vals.push(v);
                }
            }
            self.row_ptr.push(self.vals.len());
        }
    }

    /// `out = A @ x` where `x`/`out` are `[rows, h]` row-major.
    pub fn apply(&self, x: &[f32], h: usize, out: &mut [f32]) {
        out[..self.rows * h].fill(0.0);
        self.apply_acc(x, h, out);
    }

    /// `out += A @ x`.
    pub fn apply_acc(&self, x: &[f32], h: usize, out: &mut [f32]) {
        assert!(out.len() >= self.rows * h, "csr: out shape");
        for i in 0..self.rows {
            let orow = &mut out[i * h..(i + 1) * h];
            for e in self.row_ptr[i]..self.row_ptr[i + 1] {
                let c = self.cols[e] as usize;
                let v = self.vals[e];
                let xrow = &x[c * h..(c + 1) * h];
                for t in 0..h {
                    orow[t] += v * xrow[t];
                }
            }
        }
    }

    /// `out += Aᵀ @ d` — the backward scatter, using the same CSR (no
    /// transposed copy is ever built).
    pub fn apply_t_acc(&self, d: &[f32], h: usize, out: &mut [f32]) {
        for i in 0..self.rows {
            let drow = &d[i * h..(i + 1) * h];
            for e in self.row_ptr[i]..self.row_ptr[i + 1] {
                let c = self.cols[e] as usize;
                let v = self.vals[e];
                let orow = &mut out[c * h..(c + 1) * h];
                for t in 0..h {
                    orow[t] += v * drow[t];
                }
            }
        }
    }
}

/// `adj[bn,bn] @ (x[bn,d] @ w[d,h])` — ref.py `gcn_agg`. Allocating
/// reference form for the golden tests; the engine's forward runs the
/// same math through its reusable scratch instead.
pub fn gcn_agg(adj: &[f32], x: &[f32], w: &[f32], bn: usize, d: usize, h: usize) -> Vec<f32> {
    let mut z = vec![0f32; bn * h];
    mm(x, w, bn, d, h, &mut z);
    let mut csr = Csr::new();
    csr.from_dense(adj, bn, bn);
    let mut out = vec![0f32; bn * h];
    csr.apply(&z, h, &mut out);
    out
}

/// `(u ⊙ v)[s,h] @ w[h,d]` — ref.py `had_mm` (fused decoder first
/// layer). Allocating reference form for the golden tests.
pub fn had_mm(u: &[f32], v: &[f32], w: &[f32], s: usize, h: usize, d: usize) -> Vec<f32> {
    assert_eq!(u.len(), s * h, "had_mm: u shape");
    assert_eq!(v.len(), s * h, "had_mm: v shape");
    let had: Vec<f32> = u.iter().zip(v).map(|(a, b)| a * b).collect();
    let mut out = vec![0f32; s * d];
    mm(&had, w, s, h, d, &mut out);
    out
}

/// Row-wise LayerNorm over the feature axis (population variance,
/// `LN_EPS`), also emitting the normalized rows (`xhat`) and the
/// reciprocal std per row — the backward pass needs both.
pub fn layer_norm_rows(
    x: &[f32],
    rows: usize,
    h: usize,
    scale: &[f32],
    bias: &[f32],
    xhat: &mut [f32],
    rstd: &mut [f32],
    out: &mut [f32],
) {
    for i in 0..rows {
        let row = &x[i * h..(i + 1) * h];
        let mu = row.iter().sum::<f32>() / h as f32;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / h as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[i] = rs;
        for c in 0..h {
            let xh = (row[c] - mu) * rs;
            xhat[i * h + c] = xh;
            out[i * h + c] = xh * scale[c] + bias[c];
        }
    }
}

// ------------------------------------------------------------------
// Flat-parameter layout views
// ------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Enc {
    Gcn,
    Sage,
    Mlp,
    Rgcn,
}

/// Offsets of one encoder layer's tensors inside the flat vector
/// (resolved by name against the manifest layout at construction).
struct EncLayer {
    d_in: usize,
    /// gcn / mlp weight.
    w: usize,
    /// sage / rgcn self path (aliases `w` slot usage per encoder).
    w_self: usize,
    w_nbr: usize,
    basis: usize,
    coeff: usize,
    bases: usize,
    b: usize,
    ln_scale: usize,
    ln_bias: usize,
    prelu: usize,
}

struct DecLayer {
    w: usize,
    b: usize,
    prelu: Option<usize>,
    d_in: usize,
    d_out: usize,
}

enum Dec {
    Mlp(Vec<DecLayer>),
    DistMult { rel: usize },
}

fn tensor<'a>(v: &'a VariantSpec, name: &str) -> Result<&'a TensorSpec> {
    v.tensors
        .iter()
        .find(|t| t.name == name)
        .with_context(|| format!("layout of {:?} has no tensor {name:?}", v.name))
}

fn tensor_opt<'a>(v: &'a VariantSpec, name: &str) -> Option<&'a TensorSpec> {
    v.tensors.iter().find(|t| t.name == name)
}

// ------------------------------------------------------------------
// Reusable scratch
// ------------------------------------------------------------------

/// Per-encoder-layer forward state kept for the backward pass.
struct LayerScratch {
    /// `x @ w` staging (pre-aggregation).
    z: Vec<f32>,
    /// Pre-LayerNorm activations.
    pre: Vec<f32>,
    /// Normalized rows and reciprocal std (LayerNorm backward).
    xhat: Vec<f32>,
    rstd: Vec<f32>,
    /// Post-LayerNorm (pre-PReLU) and post-activation rows.
    ln_out: Vec<f32>,
    act: Vec<f32>,
    /// Materialized per-relation weights `W_r = Σ_b coeff·basis`
    /// (rgcn only; `[R, d_in, H]` flattened).
    rgcn_w: Vec<f32>,
}

/// Per-layer activations of one decoder evaluation (pos pass, neg
/// pass, or a score batch).
struct DecPass {
    e: Vec<Vec<f32>>,
    a: Vec<Vec<f32>>,
}

struct Scratch {
    csr: Vec<Csr>,
    lay: Vec<LayerScratch>,
    // Decoder (train/grad): hadamard inputs, activations, logits,
    // logit grads, hadamard grads for the pos and neg passes.
    h_pos: Vec<f32>,
    h_neg: Vec<f32>,
    pos_pass: DecPass,
    neg_pass: DecPass,
    pos_logit: Vec<f32>,
    neg_logit: Vec<f32>,
    d_pos: Vec<f32>,
    d_neg: Vec<f32>,
    d_hp: Vec<f32>,
    d_hn: Vec<f32>,
    // Backward buffers.
    grad: Vec<f32>,
    d_emb: Vec<f32>,
    d_cur: Vec<f32>,
    d_nxt: Vec<f32>,
    d_act: Vec<f32>,
    d_x: Vec<f32>,
    d_pre: Vec<f32>,
    d_ln: Vec<f32>,
    d_xhat: Vec<f32>,
    d_z: Vec<f32>,
    dwr: Vec<f32>,
    // Score entry.
    score_h: Vec<f32>,
    score_pass: DecPass,
}

impl Scratch {
    fn new(
        dims: &ModelDims,
        enc: Enc,
        enc_layers: &[EncLayer],
        dec: &Dec,
        param_total: usize,
    ) -> Scratch {
        let (bn, h, be, sb) = (
            dims.block_nodes,
            dims.hidden,
            dims.block_edges,
            dims.score_batch,
        );
        let maxd = enc_layers.iter().map(|l| l.d_in).max().unwrap_or(h).max(h);
        let n_csr = match enc {
            Enc::Mlp => 0,
            Enc::Rgcn => dims.relations,
            _ => 1,
        };
        let lay = enc_layers
            .iter()
            .map(|el| LayerScratch {
                z: vec![0.0; bn * h],
                pre: vec![0.0; bn * h],
                xhat: vec![0.0; bn * h],
                rstd: vec![0.0; bn],
                ln_out: vec![0.0; bn * h],
                act: vec![0.0; bn * h],
                rgcn_w: if enc == Enc::Rgcn {
                    vec![0.0; dims.relations * el.d_in * h]
                } else {
                    Vec::new()
                },
            })
            .collect();
        let dec_pass = |n: usize| match dec {
            Dec::Mlp(ls) => DecPass {
                e: ls.iter().map(|dl| vec![0.0; n * dl.d_out]).collect(),
                a: ls.iter().map(|dl| vec![0.0; n * dl.d_out]).collect(),
            },
            Dec::DistMult { .. } => DecPass { e: Vec::new(), a: Vec::new() },
        };
        Scratch {
            csr: (0..n_csr).map(|_| Csr::new()).collect(),
            lay,
            h_pos: vec![0.0; be * h],
            h_neg: vec![0.0; be * h],
            pos_pass: dec_pass(be),
            neg_pass: dec_pass(be),
            pos_logit: vec![0.0; be],
            neg_logit: vec![0.0; be],
            d_pos: vec![0.0; be],
            d_neg: vec![0.0; be],
            d_hp: vec![0.0; be * h],
            d_hn: vec![0.0; be * h],
            grad: vec![0.0; param_total],
            d_emb: vec![0.0; bn * h],
            d_cur: vec![0.0; be * h],
            d_nxt: vec![0.0; be * h],
            d_act: vec![0.0; bn * maxd],
            d_x: vec![0.0; bn * maxd],
            d_pre: vec![0.0; bn * h],
            d_ln: vec![0.0; bn * h],
            d_xhat: vec![0.0; bn * h],
            d_z: vec![0.0; bn * h],
            dwr: vec![0.0; maxd * h],
            score_h: vec![0.0; sb * h],
            score_pass: dec_pass(sb),
        }
    }
}

// ------------------------------------------------------------------
// The engine
// ------------------------------------------------------------------

/// One model variant executing natively. Construction resolves every
/// tensor offset by name against the manifest layout and pre-sizes
/// the scratch; after that the four entry points are alloc-free
/// except for their returned vectors.
pub struct NativeEngine {
    pub variant: VariantSpec,
    pub dims: ModelDims,
    adam: AdamHp,
    enc: Enc,
    enc_layers: Vec<EncLayer>,
    dec: Dec,
    scratch: RefCell<Scratch>,
}

impl NativeEngine {
    pub fn new(manifest: &Manifest, variant: &str) -> Result<NativeEngine> {
        let v = manifest.variant(variant)?.clone();
        let dims = manifest.dims;
        let enc = match v.encoder.as_str() {
            "gcn" => Enc::Gcn,
            "sage" => Enc::Sage,
            "mlp" => Enc::Mlp,
            "rgcn" => Enc::Rgcn,
            other => bail!("native backend: unknown encoder {other:?}"),
        };

        let mut enc_layers = Vec::new();
        let mut l = 0usize;
        while let Some(b) = tensor_opt(&v, &format!("enc{l}.b")) {
            let p = format!("enc{l}");
            let (d_in, w, w_self, w_nbr, basis, coeff, bases) = match enc {
                Enc::Gcn | Enc::Mlp => {
                    let t = tensor(&v, &format!("{p}.w"))?;
                    (t.shape[0], t.offset, 0, 0, 0, 0, 0)
                }
                Enc::Sage => {
                    let ts = tensor(&v, &format!("{p}.w_self"))?;
                    let tn = tensor(&v, &format!("{p}.w_nbr"))?;
                    (ts.shape[0], 0, ts.offset, tn.offset, 0, 0, 0)
                }
                Enc::Rgcn => {
                    let ts = tensor(&v, &format!("{p}.w_self"))?;
                    let tb = tensor(&v, &format!("{p}.basis"))?;
                    let tc = tensor(&v, &format!("{p}.coeff"))?;
                    ensure!(
                        tc.shape == vec![dims.relations, tb.shape[0]],
                        "rgcn coeff shape {:?}",
                        tc.shape
                    );
                    (ts.shape[0], 0, ts.offset, 0, tb.offset, tc.offset, tb.shape[0])
                }
            };
            enc_layers.push(EncLayer {
                d_in,
                w,
                w_self,
                w_nbr,
                basis,
                coeff,
                bases,
                b: b.offset,
                ln_scale: tensor(&v, &format!("{p}.ln_scale"))?.offset,
                ln_bias: tensor(&v, &format!("{p}.ln_bias"))?.offset,
                prelu: tensor(&v, &format!("{p}.prelu"))?.offset,
            });
            l += 1;
        }
        ensure!(!enc_layers.is_empty(), "layout of {variant:?} has no encoder layers");

        let dec = match v.decoder.as_str() {
            "distmult" => {
                let t = tensor(&v, "dec.rel")?;
                ensure!(
                    t.shape == vec![dims.relations, dims.hidden],
                    "dec.rel shape {:?}",
                    t.shape
                );
                Dec::DistMult { rel: t.offset }
            }
            "mlp" => {
                let mut layers = Vec::new();
                let mut dl = 0usize;
                while let Some(w) = tensor_opt(&v, &format!("dec{dl}.w")) {
                    layers.push(DecLayer {
                        w: w.offset,
                        b: tensor(&v, &format!("dec{dl}.b"))?.offset,
                        prelu: tensor_opt(&v, &format!("dec{dl}.prelu")).map(|t| t.offset),
                        d_in: w.shape[0],
                        d_out: w.shape[1],
                    });
                    dl += 1;
                }
                ensure!(!layers.is_empty(), "layout of {variant:?} has no decoder layers");
                ensure!(
                    layers.last().unwrap().d_out == 1,
                    "mlp decoder must end in a single logit"
                );
                ensure!(
                    layers.last().unwrap().prelu.is_none(),
                    "mlp decoder last layer must be linear"
                );
                Dec::Mlp(layers)
            }
            other => bail!("native backend: unknown decoder {other:?}"),
        };

        let scratch = Scratch::new(&dims, enc, &enc_layers, &dec, v.param_total);
        Ok(NativeEngine {
            variant: v,
            dims,
            adam: manifest.adam,
            enc,
            enc_layers,
            dec,
            scratch: RefCell::new(scratch),
        })
    }

    /// Entry-point warmup parity with the PJRT engine: nothing to
    /// compile here, the call just validates the entry names exist.
    pub fn prepare(&self, entries: &[&'static str]) -> Result<()> {
        for e in entries {
            self.variant.entry(e)?;
        }
        Ok(())
    }

    pub fn hetero(&self) -> bool {
        self.variant.hetero
    }

    pub fn param_total(&self) -> usize {
        self.variant.param_total
    }

    pub fn describe(&self) -> String {
        format!(
            "{} (native) P={} enc_layers={}",
            self.variant.name,
            self.variant.param_total,
            self.enc_layers.len()
        )
    }

    // --------------------------------------------------------------
    // Forward
    // --------------------------------------------------------------

    /// Encoder forward over one padded block, filling each layer's
    /// scratch (kept for the backward pass).
    fn forward(&self, s: &mut Scratch, params: &[f32], feats: &[f32], adj: &[f32]) -> Result<()> {
        let (bn, h, r_cnt) = (self.dims.block_nodes, self.dims.hidden, self.dims.relations);
        ensure!(
            params.len() == self.variant.param_total,
            "params len {} != {}",
            params.len(),
            self.variant.param_total
        );
        ensure!(
            feats.len() == bn * self.enc_layers[0].d_in,
            "feats len {} != {}x{}",
            feats.len(),
            bn,
            self.enc_layers[0].d_in
        );
        match self.enc {
            Enc::Mlp => {}
            Enc::Rgcn => {
                ensure!(adj.len() == r_cnt * bn * bn, "adjr len {}", adj.len());
                for r in 0..r_cnt {
                    s.csr[r].from_dense(&adj[r * bn * bn..(r + 1) * bn * bn], bn, bn);
                }
            }
            _ => {
                ensure!(adj.len() == bn * bn, "adj len {}", adj.len());
                s.csr[0].from_dense(&adj[..bn * bn], bn, bn);
            }
        }

        for l in 0..self.enc_layers.len() {
            let spec = &self.enc_layers[l];
            let d_in = spec.d_in;
            let (done, rest) = s.lay.split_at_mut(l);
            let lay = &mut rest[0];
            let x: &[f32] = if l == 0 { feats } else { &done[l - 1].act };
            match self.enc {
                Enc::Gcn => {
                    mm(x, &params[spec.w..spec.w + d_in * h], bn, d_in, h, &mut lay.z);
                    s.csr[0].apply(&lay.z, h, &mut lay.pre);
                }
                Enc::Sage => {
                    mm(
                        x,
                        &params[spec.w_self..spec.w_self + d_in * h],
                        bn,
                        d_in,
                        h,
                        &mut lay.pre,
                    );
                    mm(
                        x,
                        &params[spec.w_nbr..spec.w_nbr + d_in * h],
                        bn,
                        d_in,
                        h,
                        &mut lay.z,
                    );
                    s.csr[0].apply_acc(&lay.z, h, &mut lay.pre);
                }
                Enc::Mlp => {
                    mm(x, &params[spec.w..spec.w + d_in * h], bn, d_in, h, &mut lay.pre);
                }
                Enc::Rgcn => {
                    mm(
                        x,
                        &params[spec.w_self..spec.w_self + d_in * h],
                        bn,
                        d_in,
                        h,
                        &mut lay.pre,
                    );
                    // W_r = Σ_b coeff[r,b] · basis[b], materialized once
                    // per layer and kept for the backward pass.
                    for r in 0..r_cnt {
                        let wr = &mut lay.rgcn_w[r * d_in * h..(r + 1) * d_in * h];
                        wr.fill(0.0);
                        for bi in 0..spec.bases {
                            let c = params[spec.coeff + r * spec.bases + bi];
                            if c == 0.0 {
                                continue;
                            }
                            let basis =
                                &params[spec.basis + bi * d_in * h..spec.basis + (bi + 1) * d_in * h];
                            for (o, &bv) in wr.iter_mut().zip(basis) {
                                *o += c * bv;
                            }
                        }
                    }
                    for r in 0..r_cnt {
                        mm(
                            x,
                            &lay.rgcn_w[r * d_in * h..(r + 1) * d_in * h],
                            bn,
                            d_in,
                            h,
                            &mut lay.z,
                        );
                        s.csr[r].apply_acc(&lay.z, h, &mut lay.pre);
                    }
                }
            }
            for i in 0..bn {
                for c in 0..h {
                    lay.pre[i * h + c] += params[spec.b + c];
                }
            }
            layer_norm_rows(
                &lay.pre,
                bn,
                h,
                &params[spec.ln_scale..spec.ln_scale + h],
                &params[spec.ln_bias..spec.ln_bias + h],
                &mut lay.xhat,
                &mut lay.rstd,
                &mut lay.ln_out,
            );
            let a = params[spec.prelu];
            for t in 0..bn * h {
                let v = lay.ln_out[t];
                lay.act[t] = if v >= 0.0 { v } else { a * v };
            }
        }
        Ok(())
    }

    /// MLP-decoder forward for `n` pre-gathered hadamard rows,
    /// keeping each layer's pre/post activations in `pass`.
    fn decode_mlp_forward(
        &self,
        params: &[f32],
        h_in: &[f32],
        n: usize,
        pass: &mut DecPass,
        logit: &mut [f32],
    ) {
        let layers = match &self.dec {
            Dec::Mlp(ls) => ls,
            Dec::DistMult { .. } => unreachable!("mlp forward on distmult"),
        };
        for (li, dl) in layers.iter().enumerate() {
            {
                let x: &[f32] = if li == 0 { h_in } else { &pass.a[li - 1] };
                let e = &mut pass.e[li];
                mm(x, &params[dl.w..dl.w + dl.d_in * dl.d_out], n, dl.d_in, dl.d_out, e);
                for t in 0..n {
                    for c in 0..dl.d_out {
                        e[t * dl.d_out + c] += params[dl.b + c];
                    }
                }
            }
            let e = &pass.e[li];
            let a = &mut pass.a[li];
            if let Some(p) = dl.prelu {
                let slope = params[p];
                for (av, &ev) in a.iter_mut().zip(e.iter()) {
                    *av = if ev >= 0.0 { ev } else { slope * ev };
                }
            } else {
                a.copy_from_slice(e);
            }
        }
        // Last layer is [n, 1]: the logit column.
        logit[..n].copy_from_slice(&pass.a[layers.len() - 1][..n]);
    }

    /// MLP-decoder backward for one pass: given `d logit`, accumulate
    /// decoder weight grads into `grad` and emit the gradient w.r.t.
    /// the hadamard input rows into `d_h_out`.
    #[allow(clippy::too_many_arguments)]
    fn decode_mlp_backward(
        &self,
        params: &[f32],
        h_in: &[f32],
        n: usize,
        pass: &DecPass,
        dlogit: &[f32],
        grad: &mut [f32],
        d_cur: &mut [f32],
        d_nxt: &mut [f32],
        d_h_out: &mut [f32],
    ) {
        let layers = match &self.dec {
            Dec::Mlp(ls) => ls,
            Dec::DistMult { .. } => unreachable!("mlp backward on distmult"),
        };
        let nl = layers.len();
        d_cur[..n].copy_from_slice(&dlogit[..n]);
        for li in (0..nl).rev() {
            let dl = &layers[li];
            let (din, dout) = (dl.d_in, dl.d_out);
            // d_cur holds d(post-activation) for this layer.
            if let Some(p) = dl.prelu {
                let slope = params[p];
                let e = &pass.e[li];
                let mut da = 0f32;
                for t in 0..n * dout {
                    let ev = e[t];
                    if ev < 0.0 {
                        da += d_cur[t] * ev;
                        d_cur[t] *= slope;
                    }
                }
                grad[p] += da;
            }
            // d_cur now holds d(pre-activation) = d_e.
            let a_prev: &[f32] = if li == 0 { h_in } else { &pass.a[li - 1] };
            mm_tn_acc(
                &a_prev[..n * din],
                &d_cur[..n * dout],
                n,
                din,
                dout,
                &mut grad[dl.w..dl.w + din * dout],
            );
            for t in 0..n {
                for c in 0..dout {
                    grad[dl.b + c] += d_cur[t * dout + c];
                }
            }
            mm_nt(
                &d_cur[..n * dout],
                &params[dl.w..dl.w + din * dout],
                n,
                dout,
                din,
                &mut d_nxt[..n * din],
            );
            if li > 0 {
                d_cur[..n * din].copy_from_slice(&d_nxt[..n * din]);
            } else {
                d_h_out[..n * din].copy_from_slice(&d_nxt[..n * din]);
            }
        }
    }

    // --------------------------------------------------------------
    // Loss + gradient
    // --------------------------------------------------------------

    /// Forward + backward over one training block; leaves dL/dparams
    /// in `s.grad` and returns the masked BCE loss (mirrors
    /// `model.py::link_loss` exactly).
    fn grad_into(&self, s: &mut Scratch, params: &[f32], block: &Block) -> Result<f32> {
        let (bn, h, be) = (self.dims.block_nodes, self.dims.hidden, self.dims.block_edges);
        let nl = self.enc_layers.len();
        ensure!(block.pos_u.len() == be, "pos_u len {}", block.pos_u.len());
        ensure!(block.pos_v.len() == be, "pos_v len {}", block.pos_v.len());
        ensure!(block.neg_v.len() == be, "neg_v len {}", block.neg_v.len());
        ensure!(block.mask.len() == be, "mask len {}", block.mask.len());
        for j in 0..be {
            for (name, v) in [
                ("pos_u", block.pos_u[j]),
                ("pos_v", block.pos_v[j]),
                ("neg_v", block.neg_v[j]),
            ] {
                ensure!(
                    v >= 0 && (v as usize) < bn,
                    "{name}[{j}] = {v} out of block range {bn}"
                );
            }
        }
        let rel_off = match &self.dec {
            Dec::DistMult { rel } => {
                ensure!(block.rel.len() == be, "rel len {}", block.rel.len());
                for (j, &r) in block.rel.iter().enumerate() {
                    ensure!(
                        r >= 0 && (r as usize) < self.dims.relations,
                        "rel[{j}] = {r} out of range"
                    );
                }
                Some(*rel)
            }
            Dec::Mlp(_) => None,
        };

        self.forward(s, params, &block.feats, &block.adj)?;

        // Decoder forward: pos pair (u, v) and neg pair (u, neg_v).
        match rel_off {
            None => {
                {
                    let emb = &s.lay[nl - 1].act;
                    for j in 0..be {
                        let u = block.pos_u[j] as usize * h;
                        let v = block.pos_v[j] as usize * h;
                        let nv = block.neg_v[j] as usize * h;
                        for c in 0..h {
                            s.h_pos[j * h + c] = emb[u + c] * emb[v + c];
                            s.h_neg[j * h + c] = emb[u + c] * emb[nv + c];
                        }
                    }
                }
                self.decode_mlp_forward(params, &s.h_pos, be, &mut s.pos_pass, &mut s.pos_logit);
                self.decode_mlp_forward(params, &s.h_neg, be, &mut s.neg_pass, &mut s.neg_logit);
            }
            Some(rel) => {
                let emb = &s.lay[nl - 1].act;
                for j in 0..be {
                    let u = block.pos_u[j] as usize * h;
                    let v = block.pos_v[j] as usize * h;
                    let nv = block.neg_v[j] as usize * h;
                    let re = rel + block.rel[j] as usize * h;
                    let mut p = 0f32;
                    let mut n = 0f32;
                    for c in 0..h {
                        let ur = emb[u + c] * params[re + c];
                        p += ur * emb[v + c];
                        n += ur * emb[nv + c];
                    }
                    s.pos_logit[j] = p;
                    s.neg_logit[j] = n;
                }
            }
        }

        // Masked BCE loss and logit gradients.
        let msum: f32 = block.mask.iter().sum();
        let denom = msum.max(1.0);
        let mut loss = 0f32;
        for j in 0..be {
            let (p, n) = (s.pos_logit[j], s.neg_logit[j]);
            loss += (softplus(-p) + softplus(n)) * block.mask[j];
            s.d_pos[j] = -sigmoid(-p) * block.mask[j] / denom;
            s.d_neg[j] = sigmoid(n) * block.mask[j] / denom;
        }
        loss /= denom;

        s.grad.fill(0.0);
        s.d_emb.fill(0.0);

        // Decoder backward -> d_emb scatter.
        match rel_off {
            None => {
                self.decode_mlp_backward(
                    params, &s.h_pos, be, &s.pos_pass, &s.d_pos, &mut s.grad, &mut s.d_cur,
                    &mut s.d_nxt, &mut s.d_hp,
                );
                self.decode_mlp_backward(
                    params, &s.h_neg, be, &s.neg_pass, &s.d_neg, &mut s.grad, &mut s.d_cur,
                    &mut s.d_nxt, &mut s.d_hn,
                );
                let emb = &s.lay[nl - 1].act;
                for j in 0..be {
                    let u = block.pos_u[j] as usize * h;
                    let v = block.pos_v[j] as usize * h;
                    let nv = block.neg_v[j] as usize * h;
                    for c in 0..h {
                        // d(hadamard) flows to both endpoints of each pair.
                        s.d_emb[u + c] +=
                            s.d_hp[j * h + c] * emb[v + c] + s.d_hn[j * h + c] * emb[nv + c];
                        s.d_emb[v + c] += s.d_hp[j * h + c] * emb[u + c];
                        s.d_emb[nv + c] += s.d_hn[j * h + c] * emb[u + c];
                    }
                }
            }
            Some(rel) => {
                let emb = &s.lay[nl - 1].act;
                for j in 0..be {
                    let u = block.pos_u[j] as usize * h;
                    let v = block.pos_v[j] as usize * h;
                    let nv = block.neg_v[j] as usize * h;
                    let re = rel + block.rel[j] as usize * h;
                    let (dp, dn) = (s.d_pos[j], s.d_neg[j]);
                    for c in 0..h {
                        let rw = params[re + c];
                        let (eu, ev, en) = (emb[u + c], emb[v + c], emb[nv + c]);
                        s.d_emb[u + c] += rw * (dp * ev + dn * en);
                        s.d_emb[v + c] += dp * rw * eu;
                        s.d_emb[nv + c] += dn * rw * eu;
                        s.grad[re + c] += eu * (dp * ev + dn * en);
                    }
                }
            }
        }

        // Encoder backward, layer by layer.
        s.d_act[..bn * h].copy_from_slice(&s.d_emb);
        for l in (0..nl).rev() {
            let spec = &self.enc_layers[l];
            let d_in = spec.d_in;
            {
                let lay = &s.lay[l];
                // PReLU backward.
                let a = params[spec.prelu];
                let mut da = 0f32;
                for t in 0..bn * h {
                    let lo = lay.ln_out[t];
                    let d = s.d_act[t];
                    if lo >= 0.0 {
                        s.d_ln[t] = d;
                    } else {
                        s.d_ln[t] = d * a;
                        da += d * lo;
                    }
                }
                s.grad[spec.prelu] += da;
                // LayerNorm backward (per row, population variance).
                for i in 0..bn {
                    let rs = lay.rstd[i];
                    let xh = &lay.xhat[i * h..(i + 1) * h];
                    let mut sum1 = 0f32;
                    let mut sum2 = 0f32;
                    for c in 0..h {
                        let dln = s.d_ln[i * h + c];
                        let dxh = dln * params[spec.ln_scale + c];
                        s.d_xhat[i * h + c] = dxh;
                        sum1 += dxh;
                        sum2 += dxh * xh[c];
                        s.grad[spec.ln_scale + c] += dln * xh[c];
                        s.grad[spec.ln_bias + c] += dln;
                    }
                    let hf = h as f32;
                    for c in 0..h {
                        s.d_pre[i * h + c] =
                            rs / hf * (hf * s.d_xhat[i * h + c] - sum1 - xh[c] * sum2);
                    }
                }
                // Bias gradient.
                for t in 0..bn {
                    for c in 0..h {
                        s.grad[spec.b + c] += s.d_pre[t * h + c];
                    }
                }
            }
            let x: &[f32] = if l == 0 { &block.feats } else { &s.lay[l - 1].act };
            match self.enc {
                Enc::Gcn => {
                    s.d_z[..bn * h].fill(0.0);
                    s.csr[0].apply_t_acc(&s.d_pre, h, &mut s.d_z);
                    mm_tn_acc(x, &s.d_z, bn, d_in, h, &mut s.grad[spec.w..spec.w + d_in * h]);
                    if l > 0 {
                        mm_nt(
                            &s.d_z,
                            &params[spec.w..spec.w + d_in * h],
                            bn,
                            h,
                            d_in,
                            &mut s.d_x[..bn * d_in],
                        );
                    }
                }
                Enc::Sage => {
                    mm_tn_acc(
                        x,
                        &s.d_pre,
                        bn,
                        d_in,
                        h,
                        &mut s.grad[spec.w_self..spec.w_self + d_in * h],
                    );
                    s.d_z[..bn * h].fill(0.0);
                    s.csr[0].apply_t_acc(&s.d_pre, h, &mut s.d_z);
                    mm_tn_acc(
                        x,
                        &s.d_z,
                        bn,
                        d_in,
                        h,
                        &mut s.grad[spec.w_nbr..spec.w_nbr + d_in * h],
                    );
                    if l > 0 {
                        mm_nt(
                            &s.d_pre,
                            &params[spec.w_self..spec.w_self + d_in * h],
                            bn,
                            h,
                            d_in,
                            &mut s.d_x[..bn * d_in],
                        );
                        mm_nt_acc(
                            &s.d_z,
                            &params[spec.w_nbr..spec.w_nbr + d_in * h],
                            bn,
                            h,
                            d_in,
                            &mut s.d_x[..bn * d_in],
                        );
                    }
                }
                Enc::Mlp => {
                    mm_tn_acc(x, &s.d_pre, bn, d_in, h, &mut s.grad[spec.w..spec.w + d_in * h]);
                    if l > 0 {
                        mm_nt(
                            &s.d_pre,
                            &params[spec.w..spec.w + d_in * h],
                            bn,
                            h,
                            d_in,
                            &mut s.d_x[..bn * d_in],
                        );
                    }
                }
                Enc::Rgcn => {
                    mm_tn_acc(
                        x,
                        &s.d_pre,
                        bn,
                        d_in,
                        h,
                        &mut s.grad[spec.w_self..spec.w_self + d_in * h],
                    );
                    if l > 0 {
                        mm_nt(
                            &s.d_pre,
                            &params[spec.w_self..spec.w_self + d_in * h],
                            bn,
                            h,
                            d_in,
                            &mut s.d_x[..bn * d_in],
                        );
                    }
                    for r in 0..self.dims.relations {
                        s.d_z[..bn * h].fill(0.0);
                        s.csr[r].apply_t_acc(&s.d_pre, h, &mut s.d_z);
                        s.dwr[..d_in * h].fill(0.0);
                        mm_tn_acc(x, &s.d_z, bn, d_in, h, &mut s.dwr[..d_in * h]);
                        if l > 0 {
                            mm_nt_acc(
                                &s.d_z,
                                &s.lay[l].rgcn_w[r * d_in * h..(r + 1) * d_in * h],
                                bn,
                                h,
                                d_in,
                                &mut s.d_x[..bn * d_in],
                            );
                        }
                        // dW_r distributes over the basis decomposition:
                        // d_coeff[r,b] = <dW_r, basis_b>,
                        // d_basis_b += coeff[r,b] · dW_r.
                        for bi in 0..spec.bases {
                            let c = params[spec.coeff + r * spec.bases + bi];
                            let b0 = spec.basis + bi * d_in * h;
                            let mut dot = 0f32;
                            for t in 0..d_in * h {
                                let dw = s.dwr[t];
                                dot += dw * params[b0 + t];
                                s.grad[b0 + t] += c * dw;
                            }
                            s.grad[spec.coeff + r * spec.bases + bi] += dot;
                        }
                    }
                }
            }
            if l > 0 {
                s.d_act[..bn * d_in].copy_from_slice(&s.d_x[..bn * d_in]);
            }
        }
        Ok(loss)
    }

    // --------------------------------------------------------------
    // Entry points
    // --------------------------------------------------------------

    /// One fused Adam step on `state` from `block`. Returns the loss
    /// (computed at the pre-step parameters, like the artifact).
    pub fn train_step(&self, state: &mut ModelState, block: &Block) -> Result<f32> {
        let _sp = Span::start("engine", "train").hist(&metrics().engine_train_us);
        let s = &mut *self.scratch.borrow_mut();
        let loss = self.grad_into(s, &state.params, block)?;
        let hp = self.adam;
        let t1 = state.adam_t[0] + 1.0;
        let bc1 = 1.0 - hp.beta1.powf(t1);
        let bc2 = 1.0 - hp.beta2.powf(t1);
        for i in 0..state.params.len() {
            let g = s.grad[i];
            let m1 = hp.beta1 * state.adam_m[i] + (1.0 - hp.beta1) * g;
            let v1 = hp.beta2 * state.adam_v[i] + (1.0 - hp.beta2) * g * g;
            state.adam_m[i] = m1;
            state.adam_v[i] = v1;
            state.params[i] -= hp.lr * (m1 / bc1) / ((v1 / bc2).sqrt() + hp.eps);
        }
        state.adam_t[0] = t1;
        Ok(loss)
    }

    /// Loss + gradient w.r.t. the flat params (GGS / LLCG correction).
    pub fn grad_step(&self, params: &[f32], block: &Block) -> Result<(Vec<f32>, f32)> {
        let _sp = Span::start("engine", "grad").hist(&metrics().engine_grad_us);
        let s = &mut *self.scratch.borrow_mut();
        let loss = self.grad_into(s, params, block)?;
        Ok((s.grad.clone(), loss))
    }

    /// Node embeddings `[Bn, H]` (row-major) for one eval block.
    pub fn encode(&self, params: &[f32], block: &Block) -> Result<Vec<f32>> {
        let _sp = Span::start("engine", "encode").hist(&metrics().engine_encode_us);
        let s = &mut *self.scratch.borrow_mut();
        self.forward(s, params, &block.feats, &block.adj)?;
        Ok(s.lay[self.enc_layers.len() - 1].act.clone())
    }

    /// Decoder scores for `S` (emb_u, emb_v[, rel]) pairs.
    pub fn score(
        &self,
        params: &[f32],
        emb_u: &[f32],
        emb_v: &[f32],
        rel: &[i32],
    ) -> Result<Vec<f32>> {
        let _sp = Span::start("engine", "score").hist(&metrics().engine_score_us);
        let (sb, h) = (self.dims.score_batch, self.dims.hidden);
        ensure!(
            params.len() == self.variant.param_total,
            "params len {}",
            params.len()
        );
        ensure!(emb_u.len() == sb * h, "emb_u len {}", emb_u.len());
        ensure!(emb_v.len() == sb * h, "emb_v len {}", emb_v.len());
        let mut out = vec![0f32; sb];
        match &self.dec {
            Dec::Mlp(_) => {
                let s = &mut *self.scratch.borrow_mut();
                for (o, (&a, &b)) in s.score_h.iter_mut().zip(emb_u.iter().zip(emb_v)) {
                    *o = a * b;
                }
                self.decode_mlp_forward(params, &s.score_h, sb, &mut s.score_pass, &mut out);
            }
            Dec::DistMult { rel: roff } => {
                ensure!(rel.len() == sb, "rel len {}", rel.len());
                for j in 0..sb {
                    let r = rel[j];
                    ensure!(
                        r >= 0 && (r as usize) < self.dims.relations,
                        "rel[{j}] = {r} out of range"
                    );
                    let re = roff + r as usize * h;
                    let mut acc = 0f32;
                    for c in 0..h {
                        acc += emb_u[j * h + c] * params[re + c] * emb_v[j * h + c];
                    }
                    out[j] = acc;
                }
            }
        }
        Ok(out)
    }
}

// ------------------------------------------------------------------
// Golden-value kernel tests (always-on; mirror ref.py by hand)
// ------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn approx(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn mm_golden_2x3_3x2() {
        // [[1,2,3],[4,5,6]] @ [[7,8],[9,10],[11,12]]
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        let mut out = [0f32; 4];
        mm(&a, &b, 2, 3, 2, &mut out);
        assert_eq!(out, [58., 64., 139., 154.]);
    }

    #[test]
    fn mm_nt_golden() {
        // a @ bᵀ with b stored [n, k]: rows of b are dotted with rows of a.
        let a = [1., 2., 3., 4.]; // [2,2]
        let b = [5., 6., 7., 8.]; // [2,2] -> bᵀ = [[5,7],[6,8]]
        let mut out = [0f32; 4];
        mm_nt(&a, &b, 2, 2, 2, &mut out);
        assert_eq!(out, [17., 23., 39., 53.]);
    }

    #[test]
    fn mm_tn_golden() {
        // aᵀ @ b with a stored [k, m].
        let a = [1., 2., 3., 4.]; // aᵀ = [[1,3],[2,4]]
        let b = [5., 6., 7., 8.];
        let mut out = [0f32; 4];
        mm_tn(&a, &b, 2, 2, 2, &mut out);
        assert_eq!(out, [26., 30., 38., 44.]);
    }

    #[test]
    fn mm_matches_naive_reference_bitwise() {
        // Tiling and zero-skip must not change the per-element add
        // order; the padded (zero-row) region must stay exactly zero.
        let (m, k, n) = (37, 129, 19);
        let mut rng = Rng::new(31);
        let a: Vec<f32> = (0..m * k)
            .map(|i| if i % 7 == 0 { 0.0 } else { rng.gaussian() as f32 })
            .collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gaussian() as f32).collect();
        let mut fast = vec![0f32; m * n];
        mm(&a, &b, m, k, n, &mut fast);
        let mut naive = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for t in 0..k {
                    acc += a[i * k + t] * b[t * n + j];
                }
                naive[i * n + j] = acc;
            }
        }
        assert!(
            fast.iter().zip(&naive).all(|(x, y)| x.to_bits() == y.to_bits()
                || (*x == 0.0 && *y == 0.0)),
            "tiled matmul diverged from naive reference"
        );
    }

    #[test]
    fn csr_matches_dense_products() {
        let mut rng = Rng::new(5);
        let (bn, h) = (13, 6);
        let dense: Vec<f32> = (0..bn * bn)
            .map(|_| if rng.f64() < 0.3 { rng.gaussian() as f32 } else { 0.0 })
            .collect();
        let x: Vec<f32> = (0..bn * h).map(|_| rng.gaussian() as f32).collect();
        let mut csr = Csr::new();
        csr.from_dense(&dense, bn, bn);
        assert_eq!(csr.nnz(), dense.iter().filter(|&&v| v != 0.0).count());

        // A @ x vs dense mm.
        let mut sparse = vec![0f32; bn * h];
        csr.apply(&x, h, &mut sparse);
        let mut want = vec![0f32; bn * h];
        mm(&dense, &x, bn, bn, h, &mut want);
        for (s, w) in sparse.iter().zip(&want) {
            assert!(approx(*s, *w, 1e-6), "{s} vs {w}");
        }

        // Aᵀ @ x vs dense mm_tn.
        let mut sparse_t = vec![0f32; bn * h];
        csr.apply_t_acc(&x, h, &mut sparse_t);
        let mut want_t = vec![0f32; bn * h];
        mm_tn(&dense, &x, bn, bn, h, &mut want_t);
        for (s, w) in sparse_t.iter().zip(&want_t) {
            assert!(approx(*s, *w, 1e-6), "{s} vs {w}");
        }
    }

    #[test]
    fn gcn_agg_golden() {
        // adj = [[0,1],[1,0]], x = [[1,2],[3,4]], w = I
        // x@w = x; adj@(x@w) swaps the rows.
        let adj = [0., 1., 1., 0.];
        let x = [1., 2., 3., 4.];
        let w = [1., 0., 0., 1.];
        let out = gcn_agg(&adj, &x, &w, 2, 2, 2);
        assert_eq!(out, vec![3., 4., 1., 2.]);
    }

    #[test]
    fn had_mm_golden() {
        // u⊙v = [[2,6]]; [[2,6]] @ [[1],[1]] = [[8]]
        let u = [1., 2.];
        let v = [2., 3.];
        let w = [1., 1.];
        assert_eq!(had_mm(&u, &v, &w, 1, 2, 1), vec![8.]);
    }

    #[test]
    fn softplus_sigmoid_golden() {
        assert!(approx(softplus(0.0), std::f32::consts::LN_2, 1e-6));
        assert!(approx(softplus(10.0), 10.000046, 1e-5));
        assert!(approx(softplus(-20.0), 2.06e-9, 0.1));
        assert!(softplus(-200.0) >= 0.0, "stable for large negatives");
        assert!(approx(sigmoid(0.0), 0.5, 1e-7));
        assert!(approx(sigmoid(2.0), 0.880797, 1e-5));
    }

    #[test]
    fn layer_norm_golden() {
        // Row [1, 3]: mu = 2, var = 1 -> xhat = [-1, 1] (up to eps).
        let x = [1f32, 3.0];
        let scale = [2f32, 2.0];
        let bias = [0.5f32, 0.5];
        let mut xhat = [0f32; 2];
        let mut rstd = [0f32; 1];
        let mut out = [0f32; 2];
        layer_norm_rows(&x, 1, 2, &scale, &bias, &mut xhat, &mut rstd, &mut out);
        assert!(approx(out[0], -1.5, 1e-4), "{}", out[0]);
        assert!(approx(out[1], 2.5, 1e-4), "{}", out[1]);
        assert!(approx(rstd[0], 1.0, 1e-4));

        // All-equal row: variance 0 degrades to bias (xhat = 0).
        let x = [5f32, 5.0];
        layer_norm_rows(&x, 1, 2, &scale, &bias, &mut xhat, &mut rstd, &mut out);
        assert_eq!(out, [0.5, 0.5]);
    }
}
