//! The compute runtime: manifest-driven model variants behind a
//! backend abstraction.
//!
//! [`ComputeBackend`] covers the manifest's entry points (`train` /
//! `grad` / `encode` / `score`) plus the metadata call sites need
//! (variant, dims, `hetero`, `param_total`). Two implementations:
//!
//! - [`native::NativeEngine`] — the **default**: pure-Rust kernels
//!   (cache-blocked parallel matmul, CSR aggregation, fused Adam)
//!   mirroring `python/compile/kernels/ref.py`. Needs no artifacts,
//!   so every training path runs on a bare checkout.
//! - `pjrt::Engine` (feature `pjrt`) — the AOT fast path: compiles
//!   HLO text from `artifacts/` on a PJRT CPU client. Kept as an
//!   optional differential reference; building it requires the `xla`
//!   crate toolchain, hence the feature gate.
//!
//! Backend selection is one path for the whole binary:
//! `manifest.backend` (JSON field, default `"native"`) <
//! `RTMA_BACKEND` env var < `--backend` CLI flag — see
//! `docs/ENGINE.md`. Every call site goes through [`load_backend`],
//! which owns the failure telemetry (`engine_load_fail` counter +
//! one `engine_load_failed` event) so a bad manifest surfaces once
//! instead of as silent dead trainers.
//!
//! Thread model: [`Backend`] is deliberately **not** `Send` — the
//! PJRT client wraps raw pointers, and the native engine's scratch is
//! single-threaded by design (its matmuls parallelize internally).
//! Each trainer thread constructs its own backend, mirroring the
//! paper's per-trainer process model.

use anyhow::Result;

use crate::model::ModelState;
use crate::sampler::Block;
use crate::telemetry;

pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use manifest::{ArgSpec, EntrySpec, Manifest, ModelDims, TensorSpec, VariantSpec};
pub use native::NativeEngine;
#[cfg(feature = "pjrt")]
pub use pjrt::Engine;

/// The manifest's entry points plus the metadata the coordinator
/// needs. Implementations must mirror `python/compile/model.py`
/// exactly — the differential suite (`tests/native_engine.rs`,
/// `tests/integration.rs`) holds them to it.
pub trait ComputeBackend {
    /// Short backend tag for logs/doctor ("native" | "pjrt").
    fn backend_name(&self) -> &'static str;

    fn variant(&self) -> &VariantSpec;

    fn dims(&self) -> &ModelDims;

    fn hetero(&self) -> bool {
        self.variant().hetero
    }

    fn param_total(&self) -> usize {
        self.variant().param_total
    }

    /// Role warmup (compiles entries on PJRT; validates them on
    /// native). Trainers call this before marking ready so the
    /// server's ΔT_train clock never overlaps startup work.
    fn prepare(&self, entries: &[&'static str]) -> Result<()>;

    /// One fused Adam step on `state` from `block`; returns the loss
    /// computed at the pre-step parameters.
    fn train_step(&self, state: &mut ModelState, block: &Block) -> Result<f32>;

    /// Loss + gradient w.r.t. the flat params (GGS / LLCG correction).
    fn grad_step(&self, params: &[f32], block: &Block) -> Result<(Vec<f32>, f32)>;

    /// Node embeddings `[Bn, H]` (row-major) for one eval block.
    fn encode(&self, params: &[f32], block: &Block) -> Result<Vec<f32>>;

    /// Decoder scores for `S` (emb_u, emb_v[, rel]) pairs.
    fn score(
        &self,
        params: &[f32],
        emb_u: &[f32],
        emb_v: &[f32],
        rel: &[i32],
    ) -> Result<Vec<f32>>;

    /// Quick smoke summary used by `rtma doctor`.
    fn describe(&self) -> String;
}

/// A loaded backend. Boxed (not `Send`): one per thread.
pub type Backend = Box<dyn ComputeBackend>;

impl ComputeBackend for NativeEngine {
    fn backend_name(&self) -> &'static str {
        "native"
    }
    fn variant(&self) -> &VariantSpec {
        &self.variant
    }
    fn dims(&self) -> &ModelDims {
        &self.dims
    }
    fn prepare(&self, entries: &[&'static str]) -> Result<()> {
        NativeEngine::prepare(self, entries)
    }
    fn train_step(&self, state: &mut ModelState, block: &Block) -> Result<f32> {
        NativeEngine::train_step(self, state, block)
    }
    fn grad_step(&self, params: &[f32], block: &Block) -> Result<(Vec<f32>, f32)> {
        NativeEngine::grad_step(self, params, block)
    }
    fn encode(&self, params: &[f32], block: &Block) -> Result<Vec<f32>> {
        NativeEngine::encode(self, params, block)
    }
    fn score(
        &self,
        params: &[f32],
        emb_u: &[f32],
        emb_v: &[f32],
        rel: &[i32],
    ) -> Result<Vec<f32>> {
        NativeEngine::score(self, params, emb_u, emb_v, rel)
    }
    fn describe(&self) -> String {
        NativeEngine::describe(self)
    }
}

#[cfg(feature = "pjrt")]
impl ComputeBackend for pjrt::Engine {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }
    fn variant(&self) -> &VariantSpec {
        &self.variant
    }
    fn dims(&self) -> &ModelDims {
        &self.dims
    }
    fn prepare(&self, entries: &[&'static str]) -> Result<()> {
        pjrt::Engine::prepare(self, entries)
    }
    fn train_step(&self, state: &mut ModelState, block: &Block) -> Result<f32> {
        pjrt::Engine::train_step(self, state, block)
    }
    fn grad_step(&self, params: &[f32], block: &Block) -> Result<(Vec<f32>, f32)> {
        pjrt::Engine::grad_step(self, params, block)
    }
    fn encode(&self, params: &[f32], block: &Block) -> Result<Vec<f32>> {
        pjrt::Engine::encode(self, params, block)
    }
    fn score(
        &self,
        params: &[f32],
        emb_u: &[f32],
        emb_v: &[f32],
        rel: &[i32],
    ) -> Result<Vec<f32>> {
        pjrt::Engine::score(self, params, emb_u, emb_v, rel)
    }
    fn describe(&self) -> String {
        pjrt::Engine::describe(self)
    }
}

/// Load the backend `manifest.backend` selects, with unified failure
/// telemetry: every former `match Engine::load { Err => degrade }`
/// block now calls this, so a bad manifest logs one
/// `engine_load_failed` event (and bumps `engine_load_fail`) per
/// component instead of dying silently.
///
/// `impl_name` ("pallas" | "jnp") picks the artifact flavour on the
/// PJRT backend and is ignored by the native one.
pub fn load_backend(
    manifest: &Manifest,
    variant: &str,
    impl_name: &str,
    comp: &'static str,
) -> Result<Backend> {
    match load_backend_inner(manifest, variant, impl_name) {
        Ok(engine) => {
            telemetry::debug(
                comp,
                "engine_loaded",
                &[],
                format_args!("{}", engine.describe()),
            );
            Ok(engine)
        }
        Err(e) => {
            telemetry::metrics().engine_load_fail.inc();
            telemetry::info(
                comp,
                "engine_load_failed",
                &[],
                format_args!("backend {:?}: {e:#}", manifest.backend),
            );
            Err(e)
        }
    }
}

fn load_backend_inner(
    manifest: &Manifest,
    variant: &str,
    impl_name: &str,
) -> Result<Backend> {
    match manifest.backend.as_str() {
        "native" => {
            let _ = impl_name;
            let e = native::NativeEngine::new(manifest, variant)?;
            telemetry::metrics().engine_native_loads.inc();
            Ok(Box::new(e))
        }
        "pjrt" => {
            #[cfg(feature = "pjrt")]
            {
                let e = pjrt::Engine::load(manifest, variant, impl_name)?;
                telemetry::metrics().engine_pjrt_loads.inc();
                Ok(Box::new(e))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = impl_name;
                anyhow::bail!(
                    "backend \"pjrt\" requested but this build has no `pjrt` \
                     feature (rebuild with `--features pjrt`)"
                )
            }
        }
        other => anyhow::bail!(
            "unknown backend {other:?} (expected \"native\" or \"pjrt\")"
        ),
    }
}

/// Reused buffers for [`score_batched`]: one fixed `score_batch`-sized
/// set of padded inputs per caller, so steady-state batched scoring
/// allocates only the output it returns into.
#[derive(Debug, Default)]
pub struct ScoreScratch {
    emb_u: Vec<f32>,
    emb_v: Vec<f32>,
    rel: Vec<i32>,
}

/// Score an arbitrary number of `(emb_u, emb_v, rel)` rows through a
/// backend whose `score` entry takes *exactly* `dims().score_batch`
/// rows, chunking and zero-padding the tail. Scores append to `out`
/// in input order.
///
/// Both the evaluator's MRR pass and the serve batcher fold through
/// this one entry point. Because every backend scores rows
/// independently (the decoder is a row-wise matmul; pinned by
/// `tests/serve.rs`), the chunk boundaries and the zero padding are
/// unobservable: batched output is bit-identical to scoring each row
/// alone.
pub fn score_batched(
    engine: &dyn ComputeBackend,
    params: &[f32],
    emb_u: &[f32],
    emb_v: &[f32],
    rel: &[i32],
    scratch: &mut ScoreScratch,
    out: &mut Vec<f32>,
) -> Result<()> {
    let h = engine.dims().hidden;
    let s_len = engine.dims().score_batch;
    anyhow::ensure!(
        emb_u.len() == emb_v.len() && emb_u.len() % h == 0,
        "score_batched: emb_u {} / emb_v {} bytes, hidden {h}",
        emb_u.len(),
        emb_v.len()
    );
    let n = emb_u.len() / h;
    anyhow::ensure!(
        rel.len() == n,
        "score_batched: {n} rows but {} relation ids",
        rel.len()
    );
    scratch.emb_u.resize(s_len * h, 0.0);
    scratch.emb_v.resize(s_len * h, 0.0);
    scratch.rel.resize(s_len, 0);
    out.reserve(n);
    let mut done = 0usize;
    while done < n {
        let take = (n - done).min(s_len);
        scratch.emb_u[..take * h]
            .copy_from_slice(&emb_u[done * h..(done + take) * h]);
        scratch.emb_v[..take * h]
            .copy_from_slice(&emb_v[done * h..(done + take) * h]);
        scratch.rel[..take].copy_from_slice(&rel[done..done + take]);
        // Zero the padded tail: stale rows from the previous chunk
        // must not feed the decoder (harmless for correctness — rows
        // are independent — but NaN-poisonable on exotic backends).
        scratch.emb_u[take * h..].fill(0.0);
        scratch.emb_v[take * h..].fill(0.0);
        scratch.rel[take..].fill(0);
        let scores =
            engine.score(params, &scratch.emb_u, &scratch.emb_v, &scratch.rel)?;
        out.extend_from_slice(&scores[..take]);
        done += take;
    }
    Ok(())
}

/// Convenience: mean absolute value (used in tests/diagnostics).
pub fn mean_abs(xs: &[f32]) -> f64 {
    crate::util::stats::mean(&xs.iter().map(|x| x.abs() as f64).collect::<Vec<_>>())
}
