//! PJRT runtime: load AOT artifacts and execute them on the hot path.
//!
//! This is the only boundary to the Python-built world: it reads
//! `artifacts/manifest.json` ([`manifest`]) and compiles the referenced
//! HLO-text modules on a PJRT CPU client ([`engine`]). After `Engine`
//! construction, training/evaluation is pure rust + XLA — Python never
//! runs on the request path.
//!
//! Thread model: the `xla` crate's client/executable types wrap raw
//! pointers and are not `Send`, so **each trainer thread owns its own
//! [`engine::Engine`]** (its own client + compiled executables). That
//! mirrors the paper's per-trainer process model and makes trainers
//! fully independent between aggregations.

pub mod engine;
pub mod manifest;

pub use engine::Engine;
pub use manifest::{ArgSpec, EntrySpec, Manifest, ModelDims, TensorSpec, VariantSpec};
