//! Online inference serving: the train → deploy → query loop
//! (docs/SERVING.md).
//!
//! [`serve`] starts a TCP server answering link-scoring
//! (`QueryScore`) and top-k-neighbour (`QueryTopK`) requests over the
//! training wire protocol's framing (`comm`, tags 10–13, same
//! `MAX_FRAME` cap). The hot path is a **batching loop**: one batcher
//! thread accumulates requests for a small window
//! (`RTMA_SERVE_WINDOW_US`), then amortises the embedding gather and
//! one `ComputeBackend::score` matmul across every request in the
//! batch, in front of an LRU hot-node embedding cache ([`EmbCache`])
//! and a zero-alloc request decode into recycled scratch buffers
//! (`comm::decode_score_query_into`).
//!
//! **Canonical embeddings.** A node's embedding is computed from its
//! own single-target eval block (`sampler::build_block` with one
//! target), never from a block shared with whatever else is in the
//! batch — so it is a pure function of `(graph, node, weights)`.
//! That invariance is what makes the cache sound and batched scoring
//! bit-identical to single-request scoring (`tests/serve.rs`): the
//! batch amortises the decoder matmul and the syscalls, not the
//! block construction.
//!
//! **Live weight swap.** The paper's time-based aggregation makes
//! round boundaries natural deploy points: a co-located coordinator
//! pushes each round's new [`GlobalWeights`] (an `Arc` clone, never a
//! copy) through [`ServeHandle::push_weights`] (or
//! [`ServeHandle::follow`] on a `Control::watch_weights` channel).
//! The batcher loads the weight slot once per batch, so an in-flight
//! batch finishes entirely on the weights it started with and the
//! next batch sees the new generation — no request is ever dropped
//! or scored against a half-swapped state. A swap invalidates the
//! embedding cache (embeddings depend on weights).

#![deny(clippy::unwrap_used)]

use std::collections::{HashMap, HashSet};
use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::comm::{self, Message, Peer, WireMsg};
use crate::coordinator::kv::GlobalWeights;
use crate::graph::Graph;
use crate::runtime::{load_backend, score_batched, Manifest, ScoreScratch};
use crate::sampler::{build_block, AdjMode, EvalBlockConfig};
use crate::telemetry::{self, metrics, Span};

/// Magic + version tag of the persisted-weights file format: 8-byte
/// magic, u64 LE element count, raw f32 LE data. Written by
/// `rtma train --save-model`, read by `rtma serve`.
pub const WEIGHTS_MAGIC: &[u8; 8] = b"RTMAWTS1";

/// Persist a flat parameter vector (atomic: temp file + rename, the
/// same discipline as `graph::io`).
pub fn save_weights(path: &Path, params: &[f32]) -> Result<()> {
    let mut bytes =
        Vec::with_capacity(16 + 4 * params.len());
    bytes.extend_from_slice(WEIGHTS_MAGIC);
    bytes.extend_from_slice(&(params.len() as u64).to_le_bytes());
    for x in params {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &bytes)
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a parameter vector written by [`save_weights`], validating
/// magic and length.
pub fn load_weights(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading model {}", path.display()))?;
    ensure!(
        bytes.len() >= 16 && &bytes[..8] == WEIGHTS_MAGIC,
        "{}: not a {} weights file",
        path.display(),
        String::from_utf8_lossy(WEIGHTS_MAGIC),
    );
    let n =
        u64::from_le_bytes(crate::comm::le_bytes(&bytes[8..16])) as usize;
    ensure!(
        bytes.len() == 16 + 4 * n,
        "{}: truncated weights ({} bytes for {n} params)",
        path.display(),
        bytes.len()
    );
    Ok(bytes[16..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(crate::comm::le_bytes(c)))
        .collect())
}

/// Serving knobs. Every field has an `RTMA_SERVE_*` env override so
/// the CI smoke and the load generator can tune the window without
/// new flags (docs/SERVING.md).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (the chosen address is
    /// on [`ServeHandle::addr`] and printed by `rtma serve`).
    pub addr: String,
    /// Batching window: how long the batcher waits for more requests
    /// after the first one arrives (`RTMA_SERVE_WINDOW_US`).
    pub window: Duration,
    /// Max requests folded into one batch (`RTMA_SERVE_MAX_BATCH`).
    pub max_batch: usize,
    /// LRU embedding-cache capacity in nodes (`RTMA_SERVE_CACHE`).
    pub cache_cap: usize,
    /// Max CSR neighbours scored per top-k query
    /// (`RTMA_SERVE_TOPK_SCAN`).
    pub topk_scan: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            window: Duration::from_micros(2000),
            max_batch: 256,
            cache_cap: 4096,
            topk_scan: 512,
        }
    }
}

impl ServeConfig {
    /// Defaults with `RTMA_SERVE_*` env overrides applied.
    pub fn from_env() -> ServeConfig {
        fn env_usize(key: &str, default: usize) -> usize {
            std::env::var(key)
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(default)
        }
        let d = ServeConfig::default();
        ServeConfig {
            addr: std::env::var("RTMA_SERVE_ADDR")
                .unwrap_or(d.addr),
            window: Duration::from_micros(env_usize(
                "RTMA_SERVE_WINDOW_US",
                d.window.as_micros() as usize,
            ) as u64),
            max_batch: env_usize("RTMA_SERVE_MAX_BATCH", d.max_batch)
                .max(1),
            cache_cap: env_usize("RTMA_SERVE_CACHE", d.cache_cap).max(1),
            topk_scan: env_usize("RTMA_SERVE_TOPK_SCAN", d.topk_scan)
                .max(1),
        }
    }
}

const NO_SLOT: usize = usize::MAX;

/// Fixed-capacity LRU cache of per-node embedding rows, index-linked
/// (no per-entry allocation: one flat `f32` slab plus three `usize`
/// vectors). Keyed by global node id; tagged with the weight
/// generation that produced the rows — [`EmbCache::invalidate`]
/// drops everything when the server swaps weights, since embeddings
/// are a function of the parameters.
#[derive(Debug)]
pub struct EmbCache {
    h: usize,
    cap: usize,
    map: HashMap<u32, usize>,
    keys: Vec<u32>,
    prev: Vec<usize>,
    next: Vec<usize>,
    data: Vec<f32>,
    head: usize,
    tail: usize,
    generation: u64,
}

impl EmbCache {
    pub fn new(cap: usize, h: usize) -> EmbCache {
        let cap = cap.max(1);
        EmbCache {
            h,
            cap,
            map: HashMap::with_capacity(cap),
            keys: Vec::new(),
            prev: Vec::new(),
            next: Vec::new(),
            data: Vec::new(),
            head: NO_SLOT,
            tail: NO_SLOT,
            generation: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Weight generation the cached rows were computed under.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Drop every entry and retag the cache with `generation` (weight
    /// swap). Slot storage is kept for reuse.
    pub fn invalidate(&mut self, generation: u64) {
        self.map.clear();
        self.keys.clear();
        self.prev.clear();
        self.next.clear();
        self.head = NO_SLOT;
        self.tail = NO_SLOT;
        self.generation = generation;
    }

    /// Membership test with no LRU side effects (callers account
    /// hit/miss metrics where a miss triggers a compute).
    pub fn contains(&self, node: u32) -> bool {
        self.map.contains_key(&node)
    }

    fn detach(&mut self, i: usize) {
        let (p, n) = (self.prev[i], self.next[i]);
        if p == NO_SLOT {
            self.head = n;
        } else {
            self.next[p] = n;
        }
        if n == NO_SLOT {
            self.tail = p;
        } else {
            self.prev[n] = p;
        }
    }

    fn push_front(&mut self, i: usize) {
        self.prev[i] = NO_SLOT;
        self.next[i] = self.head;
        if self.head != NO_SLOT {
            self.prev[self.head] = i;
        }
        self.head = i;
        if self.tail == NO_SLOT {
            self.tail = i;
        }
    }

    /// The embedding row for `node`, bumping it to most-recently-used.
    pub fn get(&mut self, node: u32) -> Option<&[f32]> {
        let i = *self.map.get(&node)?;
        if self.head != i {
            self.detach(i);
            self.push_front(i);
        }
        Some(&self.data[i * self.h..(i + 1) * self.h])
    }

    /// Insert (or refresh) `node`'s embedding row, evicting the
    /// least-recently-used entry when full.
    pub fn insert(&mut self, node: u32, emb: &[f32]) {
        assert_eq!(emb.len(), self.h, "embedding width mismatch");
        if let Some(&i) = self.map.get(&node) {
            self.data[i * self.h..(i + 1) * self.h].copy_from_slice(emb);
            if self.head != i {
                self.detach(i);
                self.push_front(i);
            }
            return;
        }
        let i = if self.keys.len() < self.cap {
            // Fresh slot: grow the slab.
            let i = self.keys.len();
            self.keys.push(node);
            self.prev.push(NO_SLOT);
            self.next.push(NO_SLOT);
            self.data.extend_from_slice(emb);
            i
        } else {
            // Full: evict the LRU tail and reuse its slot.
            let i = self.tail;
            debug_assert_ne!(i, NO_SLOT);
            self.detach(i);
            self.map.remove(&self.keys[i]);
            self.keys[i] = node;
            self.data[i * self.h..(i + 1) * self.h].copy_from_slice(emb);
            i
        };
        self.map.insert(node, i);
        self.push_front(i);
    }
}

/// The swappable weight slot shared between the batcher and the
/// trainer/coordinator side. One `Mutex<(generation, Arc)>`: the
/// batcher takes one lock per *batch* (not per request) and every
/// swap is a pointer store — in-flight batches keep their loaded
/// `Arc` alive, so old weights retire only when the last batch using
/// them completes.
#[derive(Debug)]
pub struct WeightSlot {
    inner: Mutex<(u64, GlobalWeights)>,
}

impl WeightSlot {
    pub fn new(init: GlobalWeights) -> WeightSlot {
        WeightSlot { inner: Mutex::new((1, init)) }
    }

    /// Install new weights; returns the new generation.
    pub fn swap(&self, w: GlobalWeights) -> u64 {
        // A poisoned lock means a panic mid-swap; the slot's pair is
        // always internally consistent, so recover the guard.
        let mut g =
            self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        g.0 += 1;
        g.1 = w;
        metrics().serve_weight_swaps.inc();
        g.0
    }

    /// The current `(generation, weights)` — an `Arc` clone.
    pub fn load(&self) -> (u64, GlobalWeights) {
        let g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        (g.0, g.1.clone())
    }
}

/// Work items flowing reader → batcher.
enum Work {
    Open {
        conn: u64,
        writer: TcpStream,
        spent_tx: mpsc::Sender<Vec<(u32, u32, i32)>>,
    },
    Score {
        conn: u64,
        id: u64,
        pairs: Vec<(u32, u32, i32)>,
        t0: Instant,
    },
    TopK { conn: u64, id: u64, node: u32, k: u32, t0: Instant },
    Close { conn: u64 },
}

/// Handle to a running server: the bound address, the weight slot and
/// the thread set. Dropping the handle does NOT stop the server; call
/// [`ServeHandle::shutdown`] (or have a client send `Stop`).
pub struct ServeHandle {
    addr: SocketAddr,
    slot: Arc<WeightSlot>,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
}

impl ServeHandle {
    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a stop was requested (client `Stop` frame or
    /// [`ServeHandle::shutdown`]).
    pub fn stopping(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Install new weights for the *next* batch; the in-flight batch
    /// finishes on the generation it loaded. Returns the new
    /// generation.
    pub fn push_weights(&self, w: GlobalWeights) -> u64 {
        self.slot.swap(w)
    }

    /// Follow a coordinator's round broadcasts
    /// (`Control::watch_weights`): every `(round, weights)` the
    /// channel delivers is swapped in. The forwarder thread exits
    /// when the coordinator drops the channel (end of training).
    pub fn follow(&self, rx: mpsc::Receiver<(u64, GlobalWeights)>) {
        let slot = self.slot.clone();
        let shutdown = self.shutdown.clone();
        std::thread::spawn(move || {
            while let Ok((round, w)) = rx.recv() {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let generation = slot.swap(w);
                telemetry::debug(
                    "serve",
                    "weights_swapped",
                    &[
                        ("round", round as f64),
                        ("generation", generation as f64),
                    ],
                    format_args!(
                        "round {round} weights installed (gen {generation})"
                    ),
                );
            }
        });
    }

    /// Request shutdown and join every server thread.
    pub fn shutdown(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.join()
    }

    /// Join the server threads (blocks until a client `Stop` or a
    /// prior [`ServeHandle::shutdown`] request lands).
    pub fn join(self) {
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// Start serving `graph` with `init` weights; returns once the
/// listener is bound. `boundary` is the preset's bipartite boundary
/// (relation derivation for `rel = -1` queries); `manifest`/`variant`
/// pick the backend, loaded *on the batcher thread* (backends are
/// deliberately `!Send`).
pub fn serve(
    cfg: &ServeConfig,
    graph: Arc<Graph>,
    boundary: u32,
    manifest: Manifest,
    variant: String,
    impl_name: String,
    init: GlobalWeights,
) -> Result<ServeHandle> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;

    let slot = Arc::new(WeightSlot::new(init));
    let shutdown = Arc::new(AtomicBool::new(false));
    let (work_tx, work_rx) = mpsc::channel::<Work>();

    let mut threads = Vec::new();
    {
        let (slot, shutdown, cfg) =
            (slot.clone(), shutdown.clone(), cfg.clone());
        threads.push(std::thread::spawn(move || {
            batcher_loop(
                &cfg, &graph, boundary, &manifest, &variant, &impl_name,
                &slot, &shutdown, work_rx,
            );
        }));
    }
    {
        let shutdown = shutdown.clone();
        threads.push(std::thread::spawn(move || {
            acceptor_loop(listener, work_tx, shutdown);
        }));
    }
    telemetry::info(
        "serve",
        "listening",
        &[],
        format_args!("serving on {addr}"),
    );
    Ok(ServeHandle { addr, slot, shutdown, threads })
}

/// Accept loop: handshake each connection, register its writer half
/// with the batcher, spawn a reader. Polls non-blocking so a shutdown
/// request is honoured within ~20 ms.
fn acceptor_loop(
    listener: TcpListener,
    work_tx: mpsc::Sender<Work>,
    shutdown: Arc<AtomicBool>,
) {
    let live = Arc::new(AtomicU64::new(0));
    let mut next_conn = 0u64;
    let mut readers: Vec<JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                if let Err(e) = comm::serve_server_handshake(&mut stream) {
                    telemetry::debug(
                        "serve",
                        "handshake_failed",
                        &[],
                        format_args!("{e:#}"),
                    );
                    continue;
                }
                let conn = next_conn;
                next_conn += 1;
                let writer = match stream.try_clone() {
                    Ok(w) => w,
                    Err(_) => continue,
                };
                let (spent_tx, spent_rx) = mpsc::channel();
                if work_tx
                    .send(Work::Open { conn, writer, spent_tx })
                    .is_err()
                {
                    break; // batcher gone
                }
                metrics()
                    .serve_connections
                    .set(live.fetch_add(1, Ordering::Relaxed) + 1);
                let (tx, sd, lv) =
                    (work_tx.clone(), shutdown.clone(), live.clone());
                readers.push(std::thread::spawn(move || {
                    reader_loop(conn, stream, spent_rx, &tx, &sd);
                    metrics().serve_connections.set(
                        lv.fetch_sub(1, Ordering::Relaxed)
                            .saturating_sub(1),
                    );
                }));
            }
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => break,
        }
    }
    drop(work_tx); // lets the batcher's queue drain to Disconnected
    for r in readers {
        let _ = r.join();
    }
}

/// Per-connection reader: peek-poll for pending bytes (so a blocking
/// frame read never straddles a timeout and desyncs the stream),
/// decode hot-path queries zero-alloc into recycled pair buffers, and
/// forward work to the batcher. A `Stop` frame requests server-wide
/// shutdown — the serving analogue of the training protocol's stop.
fn reader_loop(
    conn: u64,
    mut stream: TcpStream,
    spent_rx: mpsc::Receiver<Vec<(u32, u32, i32)>>,
    work_tx: &mpsc::Sender<Work>,
    shutdown: &AtomicBool,
) {
    let mut rbuf: Vec<u8> = Vec::new();
    if stream.set_nonblocking(true).is_err() {
        let _ = work_tx.send(Work::Close { conn });
        return;
    }
    let mut peek = [0u8; 1];
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream.peek(&mut peek) {
            Ok(0) => break, // EOF
            Ok(_) => {}
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_micros(500));
                continue;
            }
            Err(_) => break,
        }
        // Bytes pending: take the whole frame blocking.
        if stream.set_nonblocking(false).is_err() {
            break;
        }
        let got = comm::recv_frame_into(&mut stream, &mut rbuf);
        if stream.set_nonblocking(true).is_err() {
            break;
        }
        if got.is_err() {
            break; // cap violation or mid-frame disconnect
        }
        let t0 = crate::telemetry::now();
        // Hot path: score queries decode into a recycled buffer.
        let mut pairs = spent_rx.try_recv().unwrap_or_default();
        match comm::decode_score_query_into(&rbuf, &mut pairs) {
            Ok(Some(id)) => {
                if work_tx
                    .send(Work::Score { conn, id, pairs, t0 })
                    .is_err()
                {
                    break;
                }
                continue;
            }
            Ok(None) => {}
            Err(_) => {
                metrics().comm_frames_rejected.inc();
                break;
            }
        }
        match Message::decode_from(&rbuf, Peer::ServeClient) {
            Ok(Message::QueryTopK { id, node, k }) => {
                if work_tx
                    .send(Work::TopK { conn, id, node, k, t0 })
                    .is_err()
                {
                    break;
                }
            }
            Ok(Message::Stop) => {
                telemetry::info(
                    "serve",
                    "stop_requested",
                    &[("conn", conn as f64)],
                    format_args!("client {conn} requested stop"),
                );
                shutdown.store(true, Ordering::SeqCst);
                break;
            }
            Ok(other) => {
                telemetry::debug(
                    "serve",
                    "unexpected_frame",
                    &[("conn", conn as f64)],
                    format_args!("ignoring {other:?}"),
                );
            }
            Err(_) => {
                metrics().comm_frames_rejected.inc();
                break;
            }
        }
    }
    let _ = work_tx.send(Work::Close { conn });
}

/// A registered connection's write half plus its pair-buffer recycle
/// channel.
struct ConnState {
    writer: TcpStream,
    spent_tx: mpsc::Sender<Vec<(u32, u32, i32)>>,
}

/// One request awaiting its slice of the batch score vector.
enum Pending {
    Score {
        conn: u64,
        id: u64,
        t0: Instant,
        start: usize,
        len: usize,
        pairs: Vec<(u32, u32, i32)>,
    },
    TopK {
        conn: u64,
        id: u64,
        t0: Instant,
        k: u32,
        start: usize,
        len: usize,
        cstart: usize,
    },
}

/// The batcher: owns the engine (constructed here — backends are
/// `!Send`), the embedding cache and every connection's write half.
/// See the module docs for the batch pipeline.
#[allow(clippy::too_many_arguments)]
fn batcher_loop(
    cfg: &ServeConfig,
    graph: &Graph,
    boundary: u32,
    manifest: &Manifest,
    variant: &str,
    impl_name: &str,
    slot: &WeightSlot,
    shutdown: &AtomicBool,
    work_rx: mpsc::Receiver<Work>,
) {
    let engine = match load_backend(manifest, variant, impl_name, "serve") {
        Ok(e) => e,
        Err(_) => {
            shutdown.store(true, Ordering::SeqCst);
            return;
        }
    };
    if let Err(e) = engine.prepare(&["encode", "score"]) {
        telemetry::info(
            "serve",
            "compile_failed",
            &[],
            format_args!("compile failed: {e}"),
        );
        shutdown.store(true, Ordering::SeqCst);
        return;
    }
    let dims = engine.dims();
    let h = dims.hidden;
    let relations = dims.relations;
    let block_cfg = EvalBlockConfig::new(
        dims.block_nodes,
        dims.feat_dim,
        AdjMode::for_encoder(&engine.variant().encoder),
        relations,
        boundary,
    );
    let mut cache = EmbCache::new(cfg.cache_cap, h);
    let mut conns: HashMap<u64, ConnState> = HashMap::new();

    // Reused per-batch buffers: steady state allocates nothing but
    // the fresh-embedding rows themselves.
    let mut items: Vec<Work> = Vec::new();
    let mut fresh: HashMap<u32, Vec<f32>> = HashMap::new();
    let mut invalid: HashSet<u32> = HashSet::new();
    let mut emb_u: Vec<f32> = Vec::new();
    let mut emb_v: Vec<f32> = Vec::new();
    let mut rels: Vec<i32> = Vec::new();
    let mut nan_rows: Vec<usize> = Vec::new();
    let mut cands: Vec<u32> = Vec::new();
    let mut pending: Vec<Pending> = Vec::new();
    let mut scores: Vec<f32> = Vec::new();
    let mut scratch = ScoreScratch::default();
    let mut wscratch: Vec<u8> = Vec::new();
    let mut tk: Vec<(u32, f32)> = Vec::new();

    'outer: loop {
        let first = match work_rx.recv_timeout(Duration::from_millis(50)) {
            Ok(w) => w,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        match first {
            Work::Open { conn, writer, spent_tx } => {
                conns.insert(conn, ConnState { writer, spent_tx });
                continue;
            }
            Work::Close { conn } => {
                conns.remove(&conn);
                continue;
            }
            w => items.push(w),
        }
        // Accumulate the window (control frames handled inline).
        let deadline = crate::telemetry::now() + cfg.window;
        while items.len() < cfg.max_batch {
            let now = crate::telemetry::now();
            if now >= deadline {
                break;
            }
            match work_rx.recv_timeout(deadline - now) {
                Ok(Work::Open { conn, writer, spent_tx }) => {
                    conns.insert(conn, ConnState { writer, spent_tx });
                }
                Ok(Work::Close { conn }) => {
                    conns.remove(&conn);
                }
                Ok(w) => items.push(w),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    process_batch(
                        &*engine, graph, boundary, relations, &block_cfg,
                        slot, &mut cache, &mut conns, cfg, &mut items,
                        &mut fresh, &mut invalid, &mut emb_u, &mut emb_v,
                        &mut rels, &mut nan_rows, &mut cands,
                        &mut pending, &mut scores, &mut scratch,
                        &mut wscratch, &mut tk,
                    );
                    break 'outer;
                }
            }
        }
        process_batch(
            &*engine, graph, boundary, relations, &block_cfg, slot,
            &mut cache, &mut conns, cfg, &mut items, &mut fresh,
            &mut invalid, &mut emb_u, &mut emb_v, &mut rels,
            &mut nan_rows, &mut cands, &mut pending, &mut scores,
            &mut scratch, &mut wscratch, &mut tk,
        );
    }
    telemetry::trace_counters("serve");
    telemetry::flush();
}

/// Relation id for a `rel = -1` query: derived from the bipartite
/// boundary exactly as the eval sampler derives edge relations
/// (`sampler::directional_rel` base classes), clamped into the
/// decoder's relation range.
fn derive_rel(u: u32, v: u32, boundary: u32, relations: usize) -> i32 {
    if boundary == 0 {
        return 0;
    }
    let base = u8::from(u >= boundary && v >= boundary);
    let r = crate::sampler::directional_rel(u, v, base, boundary);
    (r as usize).min(relations.saturating_sub(1)) as i32
}

/// Score one collected batch and write every reply. See module docs:
/// weights load once (swap boundary), canonical per-node embeddings
/// (cache + fresh table), one batched score, per-request replies.
#[allow(clippy::too_many_arguments)]
fn process_batch(
    engine: &dyn crate::runtime::ComputeBackend,
    graph: &Graph,
    boundary: u32,
    relations: usize,
    block_cfg: &EvalBlockConfig,
    slot: &WeightSlot,
    cache: &mut EmbCache,
    conns: &mut HashMap<u64, ConnState>,
    cfg: &ServeConfig,
    items: &mut Vec<Work>,
    fresh: &mut HashMap<u32, Vec<f32>>,
    invalid: &mut HashSet<u32>,
    emb_u: &mut Vec<f32>,
    emb_v: &mut Vec<f32>,
    rels: &mut Vec<i32>,
    nan_rows: &mut Vec<usize>,
    cands: &mut Vec<u32>,
    pending: &mut Vec<Pending>,
    scores: &mut Vec<f32>,
    scratch: &mut ScoreScratch,
    wscratch: &mut Vec<u8>,
    tk: &mut Vec<(u32, f32)>,
) {
    if items.is_empty() {
        return;
    }
    let span = Span::start("serve", "batch").hist(&metrics().serve_batch_us);
    let m = metrics();
    let h = engine.dims().hidden;
    let n_nodes = graph.num_nodes() as u32;

    // The swap boundary: this batch runs entirely on one generation.
    let (generation, weights) = slot.load();
    if generation != cache.generation() {
        cache.invalidate(generation);
    }

    // Pass 1 — make every needed embedding available (cache hit or
    // computed fresh from the node's canonical single-target block).
    fresh.clear();
    invalid.clear();
    let mut need = |node: u32,
                    fresh: &mut HashMap<u32, Vec<f32>>,
                    invalid: &mut HashSet<u32>,
                    cache: &mut EmbCache| {
        if fresh.contains_key(&node) {
            return;
        }
        if cache.contains(node) {
            m.serve_cache_hits.inc();
            return;
        }
        m.serve_cache_misses.inc();
        if node >= n_nodes {
            invalid.insert(node);
            fresh.insert(node, vec![0.0; h]);
            return;
        }
        let emb = build_block(graph, &[node], block_cfg);
        match engine.encode(&weights, &emb) {
            Ok(e) => {
                fresh.insert(node, e[..h].to_vec());
            }
            Err(err) => {
                telemetry::info(
                    "serve",
                    "encode_failed",
                    &[("node", node as f64)],
                    format_args!("node {node}: {err:#}"),
                );
                invalid.insert(node);
                fresh.insert(node, vec![0.0; h]);
            }
        }
    };
    for item in items.iter() {
        match item {
            Work::Score { pairs, .. } => {
                for &(u, v, _) in pairs {
                    need(u, fresh, invalid, cache);
                    need(v, fresh, invalid, cache);
                }
            }
            Work::TopK { node, .. } => {
                need(*node, fresh, invalid, cache);
                if *node < n_nodes {
                    for &nb in graph
                        .neighbors_of(*node as usize)
                        .iter()
                        .take(cfg.topk_scan)
                    {
                        need(nb, fresh, invalid, cache);
                    }
                }
            }
            Work::Open { .. } | Work::Close { .. } => {}
        }
    }

    // Pass 2 — assemble one flat (emb_u, emb_v, rel) schedule across
    // the whole batch.
    emb_u.clear();
    emb_v.clear();
    rels.clear();
    nan_rows.clear();
    cands.clear();
    pending.clear();
    let mut push_row = |u: u32,
                        v: u32,
                        r: i32,
                        emb_u: &mut Vec<f32>,
                        emb_v: &mut Vec<f32>,
                        rels: &mut Vec<i32>,
                        nan_rows: &mut Vec<usize>,
                        cache: &mut EmbCache,
                        fresh: &HashMap<u32, Vec<f32>>,
                        invalid: &HashSet<u32>| {
        let row = rels.len();
        for (node, dst) in [(u, &mut *emb_u), (v, &mut *emb_v)] {
            if let Some(e) = fresh.get(&node) {
                dst.extend_from_slice(e);
            } else {
                dst.extend_from_slice(
                    cache.get(node).expect("pass 1 populated every node"),
                );
            }
        }
        let rr = if r < 0 {
            derive_rel(u, v, boundary, relations)
        } else if (r as usize) < relations {
            r
        } else {
            nan_rows.push(row);
            0
        };
        rels.push(rr);
        if invalid.contains(&u) || invalid.contains(&v) {
            nan_rows.push(row);
        }
    };
    for item in items.drain(..) {
        match item {
            Work::Score { conn, id, pairs, t0 } => {
                let start = rels.len();
                for &(u, v, r) in &pairs {
                    push_row(
                        u, v, r, emb_u, emb_v, rels, nan_rows, cache,
                        fresh, invalid,
                    );
                }
                pending.push(Pending::Score {
                    conn,
                    id,
                    t0,
                    start,
                    len: rels.len() - start,
                    pairs,
                });
            }
            Work::TopK { conn, id, node, k, t0 } => {
                let start = rels.len();
                let cstart = cands.len();
                if node < n_nodes {
                    // Borrow dance: collect the capped neighbour list
                    // first (cands doubles as the reply's node column).
                    let clen = cands.len();
                    cands.extend(
                        graph
                            .neighbors_of(node as usize)
                            .iter()
                            .take(cfg.topk_scan),
                    );
                    for ci in clen..cands.len() {
                        let nb = cands[ci];
                        push_row(
                            node, nb, -1, emb_u, emb_v, rels, nan_rows,
                            cache, fresh, invalid,
                        );
                    }
                }
                pending.push(Pending::TopK {
                    conn,
                    id,
                    t0,
                    k,
                    start,
                    len: rels.len() - start,
                    cstart,
                });
            }
            Work::Open { .. } | Work::Close { .. } => unreachable!(),
        }
    }

    // Pass 3 — one batched score matmul for everything.
    scores.clear();
    if !rels.is_empty() {
        if let Err(e) = score_batched(
            engine, &weights, emb_u, emb_v, rels, scratch, scores,
        ) {
            telemetry::info(
                "serve",
                "score_failed",
                &[("rows", rels.len() as f64)],
                format_args!("batch score failed: {e:#}"),
            );
            scores.clear();
            scores.resize(rels.len(), f32::NAN);
        }
        for &row in nan_rows.iter() {
            scores[row] = f32::NAN;
        }
    }

    // Pass 4 — per-request replies, in arrival order.
    for p in pending.drain(..) {
        let (conn, id, t0, reply_pairs) = match p {
            Pending::Score { conn, id, t0, start, len, pairs } => {
                if let Some(c) = conns.get_mut(&conn) {
                    let msg = WireMsg::ReplyScore {
                        id,
                        scores: &scores[start..start + len],
                    };
                    if comm::send_wire(&mut c.writer, &msg, wscratch)
                        .is_err()
                    {
                        conns.remove(&conn);
                    }
                }
                m.serve_pairs.add(len as u64);
                (conn, id, t0, Some(pairs))
            }
            Pending::TopK { conn, id, t0, k, start, len, cstart } => {
                tk.clear();
                for i in 0..len {
                    tk.push((cands[cstart + i], scores[start + i]));
                }
                tk.sort_unstable_by(|a, b| {
                    match (a.1.is_nan(), b.1.is_nan()) {
                        (true, true) => a.0.cmp(&b.0),
                        (true, false) => std::cmp::Ordering::Greater,
                        (false, true) => std::cmp::Ordering::Less,
                        // Descending score, node id as deterministic
                        // tie-break.
                        // total_cmp == partial_cmp on the non-NaN
                        // floats this arm sees.
                        _ => b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)),
                    }
                });
                tk.truncate(k as usize);
                if let Some(c) = conns.get_mut(&conn) {
                    let msg = WireMsg::ReplyTopK { id, items: tk };
                    if comm::send_wire(&mut c.writer, &msg, wscratch)
                        .is_err()
                    {
                        conns.remove(&conn);
                    }
                }
                m.serve_pairs.add(len as u64);
                (conn, id, t0, None)
            }
        };
        let _ = id;
        m.serve_requests.inc();
        m.serve_request_us.observe(t0.elapsed().as_micros() as u64);
        // Recycle the request's pair buffer back to its reader.
        if let (Some(pairs), Some(c)) = (reply_pairs, conns.get(&conn)) {
            let _ = c.spent_tx.send(pairs);
        }
    }

    // Pass 5 — promote this batch's fresh embeddings into the cache
    // (after assembly, so an eviction can't starve the current batch;
    // invalid nodes stay out).
    for (node, emb) in fresh.drain() {
        if !invalid.contains(&node) {
            cache.insert(node, &emb);
        }
    }
    m.serve_batches.inc();
    drop(span);
}

/// Synchronous serving client: one connection, request/reply in
/// lockstep with reused scratch buffers. Used by the load generator,
/// the tests and anything embedding a query path.
pub struct ServeClient {
    stream: TcpStream,
    scratch: Vec<u8>,
    rbuf: Vec<u8>,
    next_id: u64,
}

impl ServeClient {
    pub fn connect(addr: &str, client_id: u32) -> Result<ServeClient> {
        let mut stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        comm::serve_client_handshake(&mut stream, client_id)?;
        Ok(ServeClient {
            stream,
            scratch: Vec::new(),
            rbuf: Vec::new(),
            next_id: 1,
        })
    }

    /// Score `(u, v, rel)` candidates (`rel = -1` derives from the
    /// graph boundary); one score per pair, in order.
    pub fn score(&mut self, pairs: &[(u32, u32, i32)]) -> Result<Vec<f32>> {
        let id = self.next_id;
        self.next_id += 1;
        comm::send_wire(
            &mut self.stream,
            &WireMsg::QueryScore { id, pairs },
            &mut self.scratch,
        )?;
        match comm::recv_from(
            &mut self.stream,
            &mut self.rbuf,
            Peer::ServeServer,
        )? {
            Message::ReplyScore { id: rid, scores } if rid == id => {
                ensure!(
                    scores.len() == pairs.len(),
                    "server returned {} scores for {} pairs",
                    scores.len(),
                    pairs.len()
                );
                Ok(scores)
            }
            other => bail!("expected ReplyScore #{id}, got {other:?}"),
        }
    }

    /// The `k` highest-scoring CSR neighbours of `node`.
    pub fn topk(&mut self, node: u32, k: u32) -> Result<Vec<(u32, f32)>> {
        let id = self.next_id;
        self.next_id += 1;
        comm::send_wire(
            &mut self.stream,
            &WireMsg::QueryTopK { id, node, k },
            &mut self.scratch,
        )?;
        match comm::recv_from(
            &mut self.stream,
            &mut self.rbuf,
            Peer::ServeServer,
        )? {
            Message::ReplyTopK { id: rid, items } if rid == id => Ok(items),
            other => bail!("expected ReplyTopK #{id}, got {other:?}"),
        }
    }

    /// Ask the server to shut down (all connections).
    pub fn stop(mut self) -> Result<()> {
        comm::send_wire(
            &mut self.stream,
            &WireMsg::Stop,
            &mut self.scratch,
        )
    }
}

#[cfg(test)]
// Tests assert through unwrap by design — a panic is the failure.
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn weights_file_roundtrip_and_rejects_corruption() {
        let dir = std::env::temp_dir()
            .join(format!("rtma-wts-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.bin");
        let params: Vec<f32> =
            (0..1000).map(|i| (i as f32) * 0.25 - 3.0).collect();
        save_weights(&path, &params).unwrap();
        let back = load_weights(&path).unwrap();
        assert_eq!(back.len(), params.len());
        assert!(back
            .iter()
            .zip(&params)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        // Truncation and bad magic are both refused.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load_weights(&path).is_err());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        std::fs::write(&path, &bad).unwrap();
        assert!(load_weights(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cache_lru_hit_and_evict() {
        let mut c = EmbCache::new(2, 3);
        assert!(c.is_empty());
        c.insert(1, &[1.0; 3]);
        c.insert(2, &[2.0; 3]);
        assert_eq!(c.len(), 2);
        assert!(c.contains(1) && c.contains(2));
        // Touch 1 → 2 becomes the LRU tail; inserting 3 evicts 2.
        assert_eq!(c.get(1).unwrap(), &[1.0; 3]);
        c.insert(3, &[3.0; 3]);
        assert_eq!(c.len(), 2);
        assert!(c.contains(1) && c.contains(3));
        assert!(!c.contains(2), "LRU entry must be the one evicted");
        // Re-inserting refreshes in place (no growth, new row data).
        c.insert(1, &[9.0; 3]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(1).unwrap(), &[9.0; 3]);
        // Now 3 is the tail; 4 evicts it.
        c.insert(4, &[4.0; 3]);
        assert!(!c.contains(3));
        assert!(c.contains(1) && c.contains(4));
    }

    #[test]
    fn cache_get_bumps_recency() {
        let mut c = EmbCache::new(3, 1);
        c.insert(10, &[0.1]);
        c.insert(20, &[0.2]);
        c.insert(30, &[0.3]);
        // Access order now 30, 20, 10; touching 10 makes 20 the LRU.
        assert!(c.get(10).is_some());
        c.insert(40, &[0.4]);
        assert!(!c.contains(20), "20 was LRU after 10 was bumped");
        assert!(c.contains(10) && c.contains(30) && c.contains(40));
    }

    #[test]
    fn cache_invalidate_on_generation_swap() {
        let mut c = EmbCache::new(4, 2);
        assert_eq!(c.generation(), 0);
        c.insert(1, &[1.0, 1.0]);
        c.insert(2, &[2.0, 2.0]);
        c.invalidate(7);
        assert_eq!(c.generation(), 7);
        assert!(c.is_empty(), "weight swap must drop every embedding");
        assert!(c.get(1).is_none());
        // Reusable after invalidation.
        c.insert(1, &[3.0, 3.0]);
        assert_eq!(c.get(1).unwrap(), &[3.0, 3.0]);
    }

    #[test]
    fn cache_capacity_one_degenerate() {
        let mut c = EmbCache::new(1, 2);
        c.insert(5, &[5.0, 5.0]);
        c.insert(6, &[6.0, 6.0]);
        assert_eq!(c.len(), 1);
        assert!(!c.contains(5));
        assert_eq!(c.get(6).unwrap(), &[6.0, 6.0]);
    }

    #[test]
    fn weight_slot_swap_bumps_generation_and_keeps_old_arcs() {
        let w1: GlobalWeights = Arc::from(vec![1.0f32; 4]);
        let slot = WeightSlot::new(w1.clone());
        let (g1, loaded) = slot.load();
        assert_eq!(g1, 1);
        assert!(std::ptr::eq(loaded.as_ptr(), w1.as_ptr()));
        let swaps_before =
            telemetry::snapshot().counter("serve_weight_swaps");
        let w2: GlobalWeights = Arc::from(vec![2.0f32; 4]);
        let g2 = slot.swap(w2.clone());
        assert_eq!(g2, 2);
        // The batch that loaded before the swap still holds w1 alive.
        assert_eq!(loaded[0], 1.0);
        let (g, now) = slot.load();
        assert_eq!(g, 2);
        assert!(std::ptr::eq(now.as_ptr(), w2.as_ptr()));
        let swaps_after =
            telemetry::snapshot().counter("serve_weight_swaps");
        assert_eq!(swaps_after, swaps_before + 1);
    }

    #[test]
    fn derive_rel_respects_boundary() {
        // Homogeneous graph: everything relation 0.
        assert_eq!(derive_rel(1, 2, 0, 4), 0);
        // Bipartite: query→item 0, item→query 1, item-item 2/3.
        assert_eq!(derive_rel(3, 12, 10, 4), 0);
        assert_eq!(derive_rel(12, 3, 10, 4), 1);
        assert_eq!(derive_rel(11, 14, 10, 4), 2);
        assert_eq!(derive_rel(14, 11, 10, 4), 3);
        // Single-relation decoder clamps to 0.
        assert_eq!(derive_rel(14, 11, 10, 1), 0);
    }

    #[test]
    fn serve_config_env_overrides() {
        // from_env with no vars set = defaults.
        let d = ServeConfig::default();
        assert_eq!(d.window, Duration::from_micros(2000));
        assert!(d.max_batch >= 1 && d.cache_cap >= 1);
    }
}
