//! Model state: flat parameter vector + Adam state, initialisation
//! from the manifest layout, aggregation operators φ, and a rust-side
//! Adam for the synchronous (GGS) baseline.

use crate::runtime::manifest::{AdamHp, InitKind, VariantSpec};
use crate::util::rng::Rng;

/// One trainer's learnable state: the flat parameter vector plus the
/// Adam moments the fused train artifact threads through.
#[derive(Clone, Debug)]
pub struct ModelState {
    pub params: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    /// Step counter as a 1-element f32 (matches the artifact signature).
    pub adam_t: Vec<f32>,
}

impl ModelState {
    /// Fresh state with paper-style initialisation (glorot for weight
    /// matrices, zeros/ones for biases and LayerNorm, 0.25 for PReLU —
    /// mirrored from `python/compile/model.py`'s init table).
    pub fn init(variant: &VariantSpec, rng: &mut Rng) -> ModelState {
        let mut params = vec![0f32; variant.param_total];
        for t in &variant.tensors {
            let dst = &mut params[t.offset..t.offset + t.size()];
            match t.init {
                InitKind::Zeros => {}
                InitKind::Ones => dst.iter_mut().for_each(|x| *x = 1.0),
                InitKind::Prelu => dst.iter_mut().for_each(|x| *x = 0.25),
                InitKind::Normal => {
                    dst.iter_mut()
                        .for_each(|x| *x = 0.1 * rng.gaussian() as f32);
                }
                InitKind::Glorot => {
                    // fan_in/fan_out from the trailing two dims (basis
                    // tensors [B, d, h] use d, h).
                    let dims = &t.shape;
                    let (fi, fo) = match dims.len() {
                        0 | 1 => (1usize, dims.first().copied().unwrap_or(1)),
                        n => (dims[n - 2], dims[n - 1]),
                    };
                    let limit = (6.0 / (fi + fo) as f64).sqrt();
                    dst.iter_mut().for_each(|x| {
                        *x = ((rng.f64() * 2.0 - 1.0) * limit) as f32;
                    });
                }
            }
        }
        let n = variant.param_total;
        ModelState {
            params,
            adam_m: vec![0.0; n],
            adam_v: vec![0.0; n],
            adam_t: vec![0.0; 1],
        }
    }

    /// Replace the weights (model aggregation broadcast). The paper's
    /// TMA keeps each trainer's local optimizer moments — only weights
    /// are averaged and broadcast.
    pub fn set_params(&mut self, params: &[f32]) {
        self.params.copy_from_slice(params);
    }

    pub fn step_count(&self) -> u64 {
        self.adam_t[0] as u64
    }
}

/// Model-aggregation operator φ (Alg 1 line 12).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregateOp {
    /// Plain parameter averaging — the paper found this beats
    /// loss-aware operators (§3.1).
    Mean,
    /// Inverse-loss weighting (the "more complex" alternative the
    /// paper compared against; kept for the ablation bench).
    InverseLoss,
}

/// Aggregate trainer weight vectors into the global weights.
/// `losses[i]` is trainer i's most recent training loss (used only by
/// `InverseLoss`).
pub fn aggregate(
    op: AggregateOp,
    weights: &[Vec<f32>],
    losses: &[f32],
) -> Vec<f32> {
    assert!(!weights.is_empty());
    let n = weights[0].len();
    assert!(weights.iter().all(|w| w.len() == n));
    let mut out = vec![0f32; n];
    match op {
        AggregateOp::Mean => {
            let scale = 1.0 / weights.len() as f32;
            for w in weights {
                for (o, &x) in out.iter_mut().zip(w) {
                    *o += x * scale;
                }
            }
        }
        AggregateOp::InverseLoss => {
            assert_eq!(losses.len(), weights.len());
            let inv: Vec<f32> =
                losses.iter().map(|&l| 1.0 / (l.max(1e-6))).collect();
            let total: f32 = inv.iter().sum();
            for (w, &c) in weights.iter().zip(&inv) {
                let scale = c / total;
                for (o, &x) in out.iter_mut().zip(w) {
                    *o += x * scale;
                }
            }
        }
    }
    out
}

/// Rust-side Adam for the GGS baseline (gradients are averaged across
/// trainers each step, then one shared update is applied — synchronous
/// SGD semantics). Matches the artifact's fused Adam in update rule.
pub struct Adam {
    hp: AdamHp,
    m: Vec<f32>,
    v: Vec<f32>,
    t: f32,
}

impl Adam {
    pub fn new(hp: AdamHp, n: usize) -> Adam {
        Adam { hp, m: vec![0.0; n], v: vec![0.0; n], t: 0.0 }
    }

    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        self.t += 1.0;
        let b1 = self.hp.beta1;
        let b2 = self.hp.beta2;
        let bc1 = 1.0 - b1.powf(self.t);
        let bc2 = 1.0 - b2.powf(self.t);
        for i in 0..params.len() {
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * grad[i];
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * grad[i] * grad[i];
            let m_hat = self.m[i] / bc1;
            let v_hat = self.v[i] / bc2;
            params[i] -= self.hp.lr * m_hat / (v_hat.sqrt() + self.hp.eps);
        }
    }
}

/// Average gradients into `dst` (allreduce-mean for GGS).
pub fn mean_grads(grads: &[Vec<f32>], dst: &mut Vec<f32>) {
    assert!(!grads.is_empty());
    let n = grads[0].len();
    dst.clear();
    dst.resize(n, 0.0);
    let scale = 1.0 / grads.len() as f32;
    for g in grads {
        for (d, &x) in dst.iter_mut().zip(g) {
            *d += x * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{AdamHp, TensorSpec, VariantSpec};
    use std::collections::BTreeMap;

    fn variant() -> VariantSpec {
        VariantSpec {
            name: "test".into(),
            encoder: "gcn".into(),
            decoder: "mlp".into(),
            hetero: false,
            param_total: 16 + 4 + 4 + 1 + 8,
            tensors: vec![
                TensorSpec {
                    name: "w".into(),
                    shape: vec![4, 4],
                    init: InitKind::Glorot,
                    offset: 0,
                },
                TensorSpec {
                    name: "b".into(),
                    shape: vec![4],
                    init: InitKind::Zeros,
                    offset: 16,
                },
                TensorSpec {
                    name: "ln".into(),
                    shape: vec![4],
                    init: InitKind::Ones,
                    offset: 20,
                },
                TensorSpec {
                    name: "a".into(),
                    shape: vec![1],
                    init: InitKind::Prelu,
                    offset: 24,
                },
                TensorSpec {
                    name: "rel".into(),
                    shape: vec![2, 4],
                    init: InitKind::Normal,
                    offset: 25,
                },
            ],
            entries: BTreeMap::new(),
        }
    }

    #[test]
    fn init_respects_kinds() {
        let v = variant();
        let s = ModelState::init(&v, &mut Rng::new(1));
        let w = &s.params[0..16];
        let limit = (6.0f64 / 8.0).sqrt() as f32;
        assert!(w.iter().any(|&x| x != 0.0));
        assert!(w.iter().all(|&x| x.abs() <= limit));
        assert!(s.params[16..20].iter().all(|&x| x == 0.0));
        assert!(s.params[20..24].iter().all(|&x| x == 1.0));
        assert_eq!(s.params[24], 0.25);
        assert!(s.params[25..33].iter().any(|&x| x != 0.0));
        assert!(s.adam_m.iter().all(|&x| x == 0.0));
        assert_eq!(s.adam_t, vec![0.0]);
    }

    #[test]
    fn init_deterministic_per_seed() {
        let v = variant();
        let a = ModelState::init(&v, &mut Rng::new(5));
        let b = ModelState::init(&v, &mut Rng::new(5));
        assert_eq!(a.params, b.params);
        let c = ModelState::init(&v, &mut Rng::new(6));
        assert_ne!(a.params, c.params);
    }

    #[test]
    fn mean_aggregation_averages() {
        let w = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(aggregate(AggregateOp::Mean, &w, &[]), vec![2.0, 3.0]);
    }

    #[test]
    fn inverse_loss_prefers_low_loss() {
        let w = vec![vec![0.0], vec![10.0]];
        // trainer 1 has much lower loss -> pulled toward 10
        let out = aggregate(AggregateOp::InverseLoss, &w, &[10.0, 0.1]);
        assert!(out[0] > 9.0, "{out:?}");
    }

    #[test]
    fn prop_mean_aggregation_idempotent_on_equal_weights() {
        crate::util::prop::check(30, 9, |rng: &mut Rng| {
            let n = rng.range(1, 50);
            let w: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
            let agg =
                aggregate(AggregateOp::Mean, &vec![w.clone(); 4], &[0.0; 4]);
            for (a, b) in agg.iter().zip(&w) {
                crate::prop_assert!(
                    (a - b).abs() < 1e-6,
                    "mean of copies changed: {a} vs {b}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn rust_adam_matches_reference_update() {
        // One step against a hand-computed Adam update.
        let hp = AdamHp { lr: 0.001, beta1: 0.9, beta2: 0.999, eps: 1e-8 };
        let mut adam = Adam::new(hp, 2);
        let mut p = vec![1.0f32, -1.0];
        let g = vec![0.5f32, -0.25];
        adam.step(&mut p, &g);
        for (i, &gi) in g.iter().enumerate() {
            let m_hat = gi;
            let v_hat = gi * gi;
            let expect = (if i == 0 { 1.0 } else { -1.0 })
                - 0.001 * m_hat / (v_hat.sqrt() + 1e-8);
            assert!((p[i] - expect).abs() < 1e-6, "{} vs {}", p[i], expect);
        }
    }

    #[test]
    fn mean_grads_averages() {
        let gs = vec![vec![1.0f32, 0.0], vec![3.0, 2.0]];
        let mut dst = Vec::new();
        mean_grads(&gs, &mut dst);
        assert_eq!(dst, vec![2.0, 1.0]);
    }
}
