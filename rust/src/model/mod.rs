//! Model state: flat parameter vector + Adam state, initialisation
//! from the manifest layout, aggregation operators φ, and a rust-side
//! Adam for the synchronous (GGS) baseline.

use crate::runtime::manifest::{AdamHp, InitKind, VariantSpec};
use crate::util::rng::Rng;

/// One trainer's learnable state: the flat parameter vector plus the
/// Adam moments the fused train artifact threads through.
#[derive(Clone, Debug)]
pub struct ModelState {
    pub params: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    /// Step counter as a 1-element f32 (matches the artifact signature).
    pub adam_t: Vec<f32>,
}

impl ModelState {
    /// Fresh state with paper-style initialisation (glorot for weight
    /// matrices, zeros/ones for biases and LayerNorm, 0.25 for PReLU —
    /// mirrored from `python/compile/model.py`'s init table).
    pub fn init(variant: &VariantSpec, rng: &mut Rng) -> ModelState {
        let mut params = vec![0f32; variant.param_total];
        for t in &variant.tensors {
            let dst = &mut params[t.offset..t.offset + t.size()];
            match t.init {
                InitKind::Zeros => {}
                InitKind::Ones => dst.iter_mut().for_each(|x| *x = 1.0),
                InitKind::Prelu => dst.iter_mut().for_each(|x| *x = 0.25),
                InitKind::Normal => {
                    dst.iter_mut()
                        .for_each(|x| *x = 0.1 * rng.gaussian() as f32);
                }
                InitKind::Glorot => {
                    // fan_in/fan_out from the trailing two dims (basis
                    // tensors [B, d, h] use d, h).
                    let dims = &t.shape;
                    let (fi, fo) = match dims.len() {
                        0 | 1 => (1usize, dims.first().copied().unwrap_or(1)),
                        n => (dims[n - 2], dims[n - 1]),
                    };
                    let limit = (6.0 / (fi + fo) as f64).sqrt();
                    dst.iter_mut().for_each(|x| {
                        *x = ((rng.f64() * 2.0 - 1.0) * limit) as f32;
                    });
                }
            }
        }
        let n = variant.param_total;
        ModelState {
            params,
            adam_m: vec![0.0; n],
            adam_v: vec![0.0; n],
            adam_t: vec![0.0; 1],
        }
    }

    /// Replace the weights (model aggregation broadcast). The paper's
    /// TMA keeps each trainer's local optimizer moments — only weights
    /// are averaged and broadcast.
    pub fn set_params(&mut self, params: &[f32]) {
        self.params.copy_from_slice(params);
    }

    pub fn step_count(&self) -> u64 {
        self.adam_t[0] as u64
    }
}

/// Model-aggregation operator φ (Alg 1 line 12).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregateOp {
    /// Plain parameter averaging — the paper found this beats
    /// loss-aware operators (§3.1).
    Mean,
    /// Inverse-loss weighting (the "more complex" alternative the
    /// paper compared against; kept for the ablation bench).
    InverseLoss,
}

/// Aggregate trainer weight vectors into the global weights —
/// the **staged** reference implementation of φ (every vector in
/// memory at once). The live server path streams each arriving vector
/// into a [`MeanAccum`] instead and is locked to this reference
/// bit-for-bit by `tests/aggregation.rs`. `losses[i]` is trainer i's
/// most recent training loss (used only by `InverseLoss`).
///
/// `Mean` sums in input order and scales once at the end, so a
/// streaming fold over the same vectors in the same order reproduces
/// it exactly. `InverseLoss` needs every loss before any vector can be
/// scaled, which is why it stays on the staging path (ablation bench
/// only); when the inverse-loss mass is degenerate — every loss
/// non-finite, e.g. all `inf`, so `total == 0` — it falls back to the
/// plain mean instead of scaling the global weights by NaN.
pub fn aggregate(
    op: AggregateOp,
    weights: &[Vec<f32>],
    losses: &[f32],
) -> Vec<f32> {
    assert!(!weights.is_empty());
    let n = weights[0].len();
    assert!(weights.iter().all(|w| w.len() == n));
    let mut out = vec![0f32; n];
    match op {
        AggregateOp::Mean => {
            for w in weights {
                for (o, &x) in out.iter_mut().zip(w) {
                    *o += x;
                }
            }
            let scale = 1.0 / weights.len() as f32;
            for o in out.iter_mut() {
                *o *= scale;
            }
        }
        AggregateOp::InverseLoss => {
            assert_eq!(losses.len(), weights.len());
            let inv: Vec<f32> =
                losses.iter().map(|&l| 1.0 / (l.max(1e-6))).collect();
            let total: f32 = inv.iter().sum();
            if !(total.is_finite() && total > 0.0) {
                return aggregate(AggregateOp::Mean, weights, losses);
            }
            for (w, &c) in weights.iter().zip(&inv) {
                let scale = c / total;
                for (o, &x) in out.iter_mut().zip(w) {
                    *o += x * scale;
                }
            }
        }
    }
    out
}

/// Streaming mean accumulator — the zero-clone round data plane's φ.
///
/// The round collection used to stage all `M` incoming weight vectors
/// (`Vec<Vec<f32>>`, O(M·P) bytes live at once) before reducing. A
/// `MeanAccum` folds each arriving vector into one pre-sized sum
/// buffer as it lands, so a round holds O(P) bytes however many
/// trainers report, and the buffer (plus the fold chunk plan) is
/// reusable across rounds ([`Self::reset`] — the GGS per-step
/// allreduce stages no per-gradient buffers between steps). Large
/// vectors are folded in disjoint windows across
/// worker threads ([`crate::util::threadpool::parallel_fill`]);
/// chunking never reorders per-element arithmetic, so the result is
/// bit-identical to the staged [`aggregate`]`(Mean, ..)` fed the same
/// vectors in the same order, at any worker count.
pub struct MeanAccum {
    sum: Vec<f32>,
    count: usize,
    /// Vectors folded in *base-relative* (sparse codec) form: their
    /// fold contributed `w - base` rather than `w`, so the mean must
    /// add `base_folds * base[j]` back ([`Self::mean_with`]). Zero on
    /// the dense path, where `mean`/`mean_into` stay bit-identical to
    /// the pre-codec behaviour.
    base_folds: usize,
    /// Per-worker fold window sizes and start offsets, planned once at
    /// construction (P and the worker count are fixed for the
    /// accumulator's lifetime) so [`Self::add`] plans nothing per
    /// message. Empty = serial fold.
    chunk_sizes: Vec<usize>,
    chunk_starts: Vec<usize>,
}

impl MeanAccum {
    /// Vectors shorter than this always fold serially: spawning the
    /// scoped fold threads costs tens of microseconds, so the
    /// parallel path only pays for itself well past the point where
    /// a serial pass stops fitting in that budget.
    const PAR_MIN: usize = 1 << 18;

    /// Accumulator for `n`-parameter vectors, one fold worker per
    /// available core.
    pub fn new(n: usize) -> MeanAccum {
        MeanAccum::with_workers(
            n,
            crate::util::threadpool::default_workers(),
        )
    }

    /// As [`Self::new`] with an explicit fold worker count (benches
    /// and determinism tests pin it).
    pub fn with_workers(n: usize, workers: usize) -> MeanAccum {
        assert!(workers >= 1);
        let (chunk_sizes, chunk_starts) =
            if workers <= 1 || n < Self::PAR_MIN {
                (Vec::new(), Vec::new())
            } else {
                let sizes =
                    crate::util::threadpool::even_chunks(n, workers);
                let mut next = 0usize;
                let starts: Vec<usize> = sizes
                    .iter()
                    .map(|&s| {
                        let b = next;
                        next += s;
                        b
                    })
                    .collect();
                (sizes, starts)
            };
        MeanAccum {
            sum: vec![0.0; n],
            count: 0,
            base_folds: 0,
            chunk_sizes,
            chunk_starts,
        }
    }

    /// Parameter count P this accumulator was sized for.
    pub fn len(&self) -> usize {
        self.sum.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sum.is_empty()
    }

    /// Vectors folded in since construction or the last reset.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Zero the accumulator for the next round, keeping the allocation.
    pub fn reset(&mut self) {
        self.sum.iter_mut().for_each(|x| *x = 0.0);
        self.count = 0;
        self.base_folds = 0;
    }

    /// Open one incoming vector's fold (`count += 1`) without folding
    /// any data yet — the streaming codec decode
    /// ([`crate::comm::codec::decode_fold`]) then lands the vector in
    /// pieces via [`Self::fold_at`] / [`Self::fold_sparse`].
    pub fn begin(&mut self) {
        self.count += 1;
    }

    /// Mark the vector opened by the last [`Self::begin`] as
    /// base-relative: its folds carry `w - base`, and
    /// [`Self::mean_with`] adds the shared base back once per marked
    /// vector.
    pub fn mark_base(&mut self) {
        self.base_folds += 1;
    }

    /// Base-relative vectors folded since construction/reset.
    pub fn base_folds(&self) -> usize {
        self.base_folds
    }

    /// Fold a contiguous chunk of the current vector at `offset`:
    /// `sum[offset + j] += chunk[j]`. Serial — decode chunks are small
    /// (≤ a few KiB); the dense [`Self::add`] path keeps the parallel
    /// fold.
    pub fn fold_at(&mut self, offset: usize, chunk: &[f32]) {
        assert!(offset + chunk.len() <= self.sum.len());
        for (o, &x) in self.sum[offset..offset + chunk.len()]
            .iter_mut()
            .zip(chunk)
        {
            *o += x;
        }
    }

    /// Fold sparse coordinates of the current vector:
    /// `sum[idx[t]] += vals[t]`. Callers guarantee `idx` is in range
    /// (the codec layer validates indices before folding).
    pub fn fold_sparse(&mut self, idx: &[u32], vals: &[f32]) {
        debug_assert_eq!(idx.len(), vals.len());
        for (&i, &x) in idx.iter().zip(vals) {
            self.sum[i as usize] += x;
        }
    }

    /// Fold one trainer's vector in: `sum[j] += w[j]`.
    pub fn add(&mut self, w: &[f32]) {
        assert_eq!(
            w.len(),
            self.sum.len(),
            "weight vector length mismatch"
        );
        self.count += 1;
        if self.chunk_sizes.is_empty() {
            for (o, &x) in self.sum.iter_mut().zip(w) {
                *o += x;
            }
            return;
        }
        let starts = &self.chunk_starts;
        crate::util::threadpool::parallel_fill(
            &mut self.sum,
            &self.chunk_sizes,
            self.chunk_sizes.len(),
            |i, win| {
                let src = &w[starts[i]..starts[i] + win.len()];
                for (o, &x) in win.iter_mut().zip(src) {
                    *o += x;
                }
            },
        );
    }

    /// The mean of the folded vectors (`sum[j] * (1/count)`), as a
    /// fresh vector.
    pub fn mean(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.sum.len());
        self.mean_into(&mut out);
        out
    }

    /// As [`Self::mean`], writing into a reused buffer (the GGS
    /// allreduce calls this every global step with the same `dst`).
    pub fn mean_into(&self, dst: &mut Vec<f32>) {
        assert!(self.count > 0, "mean of zero folded vectors");
        assert_eq!(
            self.base_folds, 0,
            "base-relative folds need mean_with(Some(base))"
        );
        let scale = 1.0 / self.count as f32;
        dst.clear();
        dst.extend(self.sum.iter().map(|&x| x * scale));
    }

    /// Mean when some folds were base-relative:
    /// `(sum[j] + base_folds·base[j]) * (1/count)`. `None` means an
    /// all-zero base — the codec module's "empty base = zeros"
    /// convention, which is exactly a gradient allreduce. With zero
    /// base-relative folds this takes the [`Self::mean_into`] path and
    /// is bit-identical to [`Self::mean`] — the identity-codec and
    /// in-process dense rounds keep their pre-codec bits.
    pub fn mean_with(&self, base: Option<&[f32]>) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.sum.len());
        self.mean_with_into(base, &mut out);
        out
    }

    /// As [`Self::mean_with`], writing into a reused buffer (the GGS
    /// allreduce under a codec).
    pub fn mean_with_into(&self, base: Option<&[f32]>, dst: &mut Vec<f32>) {
        if self.base_folds == 0 {
            self.mean_into(dst);
            return;
        }
        assert!(self.count > 0, "mean of zero folded vectors");
        let k = self.base_folds as f32;
        let scale = 1.0 / self.count as f32;
        dst.clear();
        match base {
            Some(base) => {
                assert_eq!(base.len(), self.sum.len());
                dst.extend(
                    self.sum
                        .iter()
                        .zip(base)
                        .map(|(&s, &b)| (s + k * b) * scale),
                );
            }
            None => dst.extend(self.sum.iter().map(|&s| s * scale)),
        }
    }
}

/// Rust-side Adam for the GGS baseline (gradients are averaged across
/// trainers each step, then one shared update is applied — synchronous
/// SGD semantics). Matches the artifact's fused Adam in update rule.
pub struct Adam {
    hp: AdamHp,
    m: Vec<f32>,
    v: Vec<f32>,
    t: f32,
}

impl Adam {
    pub fn new(hp: AdamHp, n: usize) -> Adam {
        Adam { hp, m: vec![0.0; n], v: vec![0.0; n], t: 0.0 }
    }

    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        self.t += 1.0;
        let b1 = self.hp.beta1;
        let b2 = self.hp.beta2;
        let bc1 = 1.0 - b1.powf(self.t);
        let bc2 = 1.0 - b2.powf(self.t);
        for i in 0..params.len() {
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * grad[i];
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * grad[i] * grad[i];
            let m_hat = self.m[i] / bc1;
            let v_hat = self.v[i] / bc2;
            params[i] -= self.hp.lr * m_hat / (v_hat.sqrt() + self.hp.eps);
        }
    }
}

/// Average gradients into `dst` — the staged reference for the GGS
/// allreduce-mean. Sum-then-scale in input order, so the streaming
/// [`MeanAccum`] fold the live `ggs_server` uses reproduces it
/// bit-for-bit.
pub fn mean_grads(grads: &[Vec<f32>], dst: &mut Vec<f32>) {
    assert!(!grads.is_empty());
    let n = grads[0].len();
    dst.clear();
    dst.resize(n, 0.0);
    for g in grads {
        for (d, &x) in dst.iter_mut().zip(g) {
            *d += x;
        }
    }
    let scale = 1.0 / grads.len() as f32;
    for d in dst.iter_mut() {
        *d *= scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{AdamHp, TensorSpec, VariantSpec};
    use std::collections::BTreeMap;

    fn variant() -> VariantSpec {
        VariantSpec {
            name: "test".into(),
            encoder: "gcn".into(),
            decoder: "mlp".into(),
            hetero: false,
            param_total: 16 + 4 + 4 + 1 + 8,
            tensors: vec![
                TensorSpec {
                    name: "w".into(),
                    shape: vec![4, 4],
                    init: InitKind::Glorot,
                    offset: 0,
                },
                TensorSpec {
                    name: "b".into(),
                    shape: vec![4],
                    init: InitKind::Zeros,
                    offset: 16,
                },
                TensorSpec {
                    name: "ln".into(),
                    shape: vec![4],
                    init: InitKind::Ones,
                    offset: 20,
                },
                TensorSpec {
                    name: "a".into(),
                    shape: vec![1],
                    init: InitKind::Prelu,
                    offset: 24,
                },
                TensorSpec {
                    name: "rel".into(),
                    shape: vec![2, 4],
                    init: InitKind::Normal,
                    offset: 25,
                },
            ],
            entries: BTreeMap::new(),
        }
    }

    #[test]
    fn init_respects_kinds() {
        let v = variant();
        let s = ModelState::init(&v, &mut Rng::new(1));
        let w = &s.params[0..16];
        let limit = (6.0f64 / 8.0).sqrt() as f32;
        assert!(w.iter().any(|&x| x != 0.0));
        assert!(w.iter().all(|&x| x.abs() <= limit));
        assert!(s.params[16..20].iter().all(|&x| x == 0.0));
        assert!(s.params[20..24].iter().all(|&x| x == 1.0));
        assert_eq!(s.params[24], 0.25);
        assert!(s.params[25..33].iter().any(|&x| x != 0.0));
        assert!(s.adam_m.iter().all(|&x| x == 0.0));
        assert_eq!(s.adam_t, vec![0.0]);
    }

    #[test]
    fn init_deterministic_per_seed() {
        let v = variant();
        let a = ModelState::init(&v, &mut Rng::new(5));
        let b = ModelState::init(&v, &mut Rng::new(5));
        assert_eq!(a.params, b.params);
        let c = ModelState::init(&v, &mut Rng::new(6));
        assert_ne!(a.params, c.params);
    }

    #[test]
    fn mean_aggregation_averages() {
        let w = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(aggregate(AggregateOp::Mean, &w, &[]), vec![2.0, 3.0]);
    }

    #[test]
    fn inverse_loss_prefers_low_loss() {
        let w = vec![vec![0.0], vec![10.0]];
        // trainer 1 has much lower loss -> pulled toward 10
        let out = aggregate(AggregateOp::InverseLoss, &w, &[10.0, 0.1]);
        assert!(out[0] > 9.0, "{out:?}");
    }

    #[test]
    fn prop_mean_aggregation_idempotent_on_equal_weights() {
        crate::util::prop::check(30, 9, |rng: &mut Rng| {
            let n = rng.range(1, 50);
            let w: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
            let agg =
                aggregate(AggregateOp::Mean, &vec![w.clone(); 4], &[0.0; 4]);
            for (a, b) in agg.iter().zip(&w) {
                crate::prop_assert!(
                    (a - b).abs() < 1e-6,
                    "mean of copies changed: {a} vs {b}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn rust_adam_matches_reference_update() {
        // One step against a hand-computed Adam update.
        let hp = AdamHp { lr: 0.001, beta1: 0.9, beta2: 0.999, eps: 1e-8 };
        let mut adam = Adam::new(hp, 2);
        let mut p = vec![1.0f32, -1.0];
        let g = vec![0.5f32, -0.25];
        adam.step(&mut p, &g);
        for (i, &gi) in g.iter().enumerate() {
            let m_hat = gi;
            let v_hat = gi * gi;
            let expect = (if i == 0 { 1.0 } else { -1.0 })
                - 0.001 * m_hat / (v_hat.sqrt() + 1e-8);
            assert!((p[i] - expect).abs() < 1e-6, "{} vs {}", p[i], expect);
        }
    }

    #[test]
    fn mean_grads_averages() {
        let gs = vec![vec![1.0f32, 0.0], vec![3.0, 2.0]];
        let mut dst = Vec::new();
        mean_grads(&gs, &mut dst);
        assert_eq!(dst, vec![2.0, 1.0]);
    }

    #[test]
    fn inverse_loss_all_nonfinite_falls_back_to_mean() {
        // All-inf losses used to drive total == 0 and scale the global
        // weights by 0/0 = NaN. The degenerate case must produce the
        // plain mean instead.
        let w = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let inf = f32::INFINITY;
        let out = aggregate(AggregateOp::InverseLoss, &w, &[inf, inf]);
        assert!(out.iter().all(|x| x.is_finite()), "NaN weights: {out:?}");
        assert_eq!(out, aggregate(AggregateOp::Mean, &w, &[inf, inf]));
        // A NaN total (inf - inf style inputs can't happen here, but
        // inf + finite can): one inf loss among finite ones just drops
        // that trainer's mass, it must NOT trip the fallback.
        let out = aggregate(AggregateOp::InverseLoss, &w, &[inf, 1.0]);
        assert!(
            out.iter().zip(&w[1]).all(|(a, b)| (a - b).abs() < 1e-6),
            "finite-loss trainer should dominate: {out:?}"
        );
    }

    #[test]
    fn mean_accum_matches_staged_aggregate_bitwise() {
        crate::util::prop::check(40, 11, |rng: &mut Rng| {
            let m = rng.range(1, 9);
            let p = rng.range(1, 300);
            let weights: Vec<Vec<f32>> = (0..m)
                .map(|_| {
                    (0..p).map(|_| rng.gaussian() as f32 * 3.0).collect()
                })
                .collect();
            let staged =
                aggregate(AggregateOp::Mean, &weights, &vec![0.0; m]);
            let mut acc = MeanAccum::with_workers(p, 1);
            for w in &weights {
                acc.add(w);
            }
            let streamed = acc.mean();
            crate::prop_assert!(
                staged
                    .iter()
                    .zip(&streamed)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "streaming fold diverged from staged reference \
                 (m={m} p={p})"
            );
            Ok(())
        });
    }

    #[test]
    fn mean_accum_parallel_fold_is_bit_deterministic() {
        // Above the serial threshold the fold splits across workers;
        // disjoint windows never reorder per-element arithmetic, so
        // any worker count gives the same bits.
        let p = MeanAccum::PAR_MIN + 1234;
        let mut rng = Rng::new(7);
        let weights: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..p).map(|_| rng.gaussian() as f32).collect())
            .collect();
        let fold = |workers: usize| -> Vec<f32> {
            let mut acc = MeanAccum::with_workers(p, workers);
            for w in &weights {
                acc.add(w);
            }
            acc.mean()
        };
        let serial = fold(1);
        for workers in [2, 4] {
            let par = fold(workers);
            assert!(
                serial
                    .iter()
                    .zip(&par)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "workers={workers} changed the fold"
            );
        }
    }

    #[test]
    fn mean_accum_reset_reuses_buffer() {
        let mut acc = MeanAccum::with_workers(2, 1);
        acc.add(&[1.0, 2.0]);
        acc.add(&[3.0, 4.0]);
        assert_eq!(acc.count(), 2);
        assert_eq!(acc.mean(), vec![2.0, 3.0]);
        acc.reset();
        assert_eq!(acc.count(), 0);
        acc.add(&[10.0, 20.0]);
        assert_eq!(acc.mean(), vec![10.0, 20.0]);
        let mut dst = Vec::new();
        acc.mean_into(&mut dst);
        assert_eq!(dst, vec![10.0, 20.0]);
    }

    #[test]
    fn mean_accum_chunked_fold_matches_add_bitwise() {
        // begin() + fold_at chunks must reproduce add() exactly: same
        // per-element order, just landed in pieces.
        let p = 1000;
        let mut rng = Rng::new(13);
        let w: Vec<f32> = (0..p).map(|_| rng.gaussian() as f32).collect();
        let mut a = MeanAccum::with_workers(p, 1);
        a.add(&w);
        let mut b = MeanAccum::with_workers(p, 1);
        b.begin();
        for off in (0..p).step_by(64) {
            b.fold_at(off, &w[off..(off + 64).min(p)]);
        }
        assert_eq!(b.count(), 1);
        assert!(a
            .mean()
            .iter()
            .zip(&b.mean())
            .all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn mean_accum_base_relative_fold_recovers_mean() {
        // Two dense vectors plus one shipped as (w - base) sparse
        // coordinates: mean_with(base) must match the staged mean of
        // the three dense vectors.
        let base = vec![1.0f32, -2.0, 3.0, 0.5];
        let dense1 = vec![1.5f32, -2.0, 3.0, 0.5];
        let dense2 = vec![1.0f32, -1.0, 3.0, 0.5];
        let sparse_w = vec![1.0f32, -2.0, 5.0, 0.5]; // differs at j=2
        let mut acc = MeanAccum::with_workers(4, 1);
        acc.add(&dense1);
        acc.add(&dense2);
        acc.begin();
        acc.mark_base();
        acc.fold_sparse(&[2], &[sparse_w[2] - base[2]]);
        assert_eq!(acc.base_folds(), 1);
        let got = acc.mean_with(Some(&base));
        let want =
            aggregate(AggregateOp::Mean, &[dense1, dense2, sparse_w], &[]);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6, "{got:?} vs {want:?}");
        }
    }

    #[test]
    fn mean_with_no_base_folds_is_bitwise_mean() {
        let mut rng = Rng::new(17);
        let p = 257;
        let mut acc = MeanAccum::with_workers(p, 1);
        for _ in 0..3 {
            let w: Vec<f32> =
                (0..p).map(|_| rng.gaussian() as f32).collect();
            acc.add(&w);
        }
        let a = acc.mean();
        let b = acc.mean_with(Some(&vec![9.0; p]));
        let c = acc.mean_with(None);
        assert!(a
            .iter()
            .zip(&b)
            .zip(&c)
            .all(|((x, y), z)| {
                x.to_bits() == y.to_bits() && x.to_bits() == z.to_bits()
            }));
    }

    #[test]
    fn mean_accum_matches_mean_grads_bitwise() {
        let gs: Vec<Vec<f32>> = vec![
            vec![0.1, -0.7, 3.5, 0.0],
            vec![2.0, 0.3, -1.25, 9.0],
            vec![-0.5, 0.0, 0.75, 1.0],
        ];
        let mut staged = Vec::new();
        mean_grads(&gs, &mut staged);
        let mut acc = MeanAccum::with_workers(4, 1);
        for g in &gs {
            acc.add(g);
        }
        let streamed = acc.mean();
        assert!(staged
            .iter()
            .zip(&streamed)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
